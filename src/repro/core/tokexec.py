"""Two-pass vectorized executor for LZ4-framed token streams.

Both of our from-scratch LZ codecs emit the same sequence framing (LZ4's
block format; ``repro_deflate`` widens the offset to 3 bytes so large
windows fit)::

  sequence := token | [litlen ext 255*] | literals | offset
              | [matchlen ext 255*]
  token    := (literal_length:4 | match_length-4 :4)

The old decoders walked this serially, one Python iteration per sequence,
interleaving header arithmetic with byte copies.  Per-sequence Python cost
only *matters* when sequences are short and plentiful, so the entry point
:func:`decode_token_stream` probes the first few hundred sequences and
routes by density:

* **sparse / mid-density streams** (long literal runs, incompressible
  data): the single-pass serial decoder is already memcpy-bound — kept as
  :func:`_decode_serial` and used directly.
* **dense streams** (many short sequences): the two-pass vectorized path.

**Pass 1 — parse** (:func:`_parse_vector`): token fields and the per-token
step (distance to the next token) are computed *speculatively for every
byte position* in ~10 vector passes — cheap, because a dense stream has
few bytes per sequence.  The serial dependency (each header's position
depends on the previous literal length) collapses to pointer-chasing the
step table, done eight sequences per Python iteration through composed
jump tables (``step``, ``step²``, ``step⁴``, ``step⁸``) and re-expanded
vectorized.  Extension-byte runs (rare) are patched sparsely: the run of
0xFF bytes at q ends at the first non-0xFF position, found by one
``searchsorted`` against the positions of all non-0xFF bytes.

**Pass 2 — execute** (:func:`execute_sequences`): one cumulative sum
yields every output position.  Literal runs are either scattered in a
single vectorized gather (many short runs) or sliced per run (few long
runs).  Matches are the only true serial chain — a match may read bytes
produced by an earlier one — but any *contiguous run* of matches whose
sources lie entirely below the first pending match's output start can be
replayed at once: every source byte is already final and every
destination is disjoint.  Those run boundaries are exact and vectorized:
a non-overlapping match always has ``ref_end <= out_start``, so within a
segment free of overlapping matches the first conflict for frontier ``o``
is ``searchsorted(running_max(ref_end), o)``.  Each batch then executes
as two numpy calls over slices of globally precomputed gather indices.
Close-referencing streams (tiny distances, e.g. byte-plane shuffles)
degrade to a lean serial memcpy loop instead of paying batch overhead.
"""

from __future__ import annotations

import numpy as np

__all__ = ["parse_sequences", "execute_sequences", "decode_token_stream"]

_MIN_MATCH = 4
_VECTOR_MIN = 4096        # below this blob size, serial always wins
_PROBE_SEQS = 256         # sequences scanned to estimate density
_SERIAL_DENSITY = 32      # >= this many comp bytes/seq: serial decoder wins
_SCATTER_MAX_RUN = 16     # mean literal run where scatter beats memcpy
_BATCH_MIN = 16           # smallest match batch worth numpy dispatch


# ---------------------------------------------------------------------------
# serial reference decoder (sparse/mid-density route + small blobs)
# ---------------------------------------------------------------------------

def _decode_serial(comp: bytes, prefix: bytes, orig_len: int, base: int,
                   offset_bytes: int, name: str) -> bytes:
    plen = len(prefix)
    dst = bytearray(plen + orig_len)
    dst[:plen] = prefix
    i = base
    o = plen
    n = len(comp)
    while i < n:
        token = comp[i]
        i += 1
        litlen = token >> 4
        if litlen == 15:
            while True:
                b = comp[i]
                i += 1
                litlen += b
                if b != 255:
                    break
        if litlen:
            dst[o:o + litlen] = comp[i:i + litlen]
            i += litlen
            o += litlen
        if i >= n:
            break  # last sequence: literals only
        if offset_bytes == 2:
            dist = comp[i] | (comp[i + 1] << 8)
        else:
            dist = comp[i] | (comp[i + 1] << 8) | (comp[i + 2] << 16)
        i += offset_bytes
        mlen = (token & 0xF) + _MIN_MATCH
        if (token & 0xF) == 15:
            while True:
                b = comp[i]
                i += 1
                mlen += b
                if b != 255:
                    break
        ref = o - dist
        if dist >= mlen:  # non-overlapping: one slice copy
            dst[o:o + mlen] = dst[ref:ref + mlen]
            o += mlen
        else:             # overlapping match: replicate pattern
            while mlen > 0:
                chunk = min(mlen, o - ref)
                dst[o:o + chunk] = dst[ref:ref + chunk]
                o += chunk
                mlen -= chunk
    if o - plen != orig_len:
        raise ValueError(f"{name} decoded {o - plen} bytes, expected {orig_len}")
    return bytes(memoryview(dst)[plen:])


# ---------------------------------------------------------------------------
# pass 1: parse
# ---------------------------------------------------------------------------

def _scan_scalar(comp: bytes, base: int, offset_bytes: int,
                 max_seqs: int | None):
    """Scalar header scan (up to ``max_seqs``); returns raw scan state."""
    n = len(comp)
    tpos: list[int] = []
    ll_fix: list[tuple[int, int, int]] = []   # (seq, litlen, n ext bytes)
    ml_fix: list[tuple[int, int]] = []        # (seq, matchlen)
    last_literal_only = False
    append = tpos.append
    i = base
    while i < n:
        if max_seqs is not None and len(tpos) >= max_seqs:
            break
        append(i)
        token = comp[i]
        i += 1
        ll = token >> 4
        if ll == 15:
            nx = 0
            while True:
                b = comp[i]
                i += 1
                nx += 1
                ll += b
                if b != 255:
                    break
            ll_fix.append((len(tpos) - 1, ll, nx))
        i += ll
        if i >= n:
            last_literal_only = True
            break
        i += offset_bytes
        if token & 15 == 15:
            ml = 15 + _MIN_MATCH
            while True:
                b = comp[i]
                i += 1
                ml += b
                if b != 255:
                    break
            ml_fix.append((len(tpos) - 1, ml))
    done = last_literal_only or i >= n
    return tpos, ll_fix, ml_fix, i, done, last_literal_only


def _scalar_arrays(comp: bytes, state, offset_bytes: int):
    """Build (lit_src, lit_len, mlens, dists) from a scalar scan state."""
    tpos, ll_fix, ml_fix, i_end, _done, last_literal_only = state
    k = len(tpos)
    tp = np.asarray(tpos, dtype=np.int32)
    # all gathered indices (tp, opos+2) are bounded by the scan end, so pad
    # only that prefix instead of copying a possibly-multi-MB blob
    carr = np.frombuffer(comp[:i_end] + b"\x00" * 4, dtype=np.uint8)
    tokens = carr[tp] if k else np.zeros(0, dtype=np.uint8)
    lit_len = (tokens >> 4).astype(np.int32)
    lit_src = tp + 1
    mlens = (tokens & 15).astype(np.int32) + _MIN_MATCH
    for s, ll, nx in ll_fix:
        lit_len[s] = ll
        lit_src[s] += nx
    for s, ml in ml_fix:
        mlens[s] = ml
    opos = lit_src + lit_len
    dists = carr[opos].astype(np.int32) | (carr[opos + 1].astype(np.int32) << 8)
    if offset_bytes == 3:
        dists |= carr[opos + 2].astype(np.int32) << 16
    if last_literal_only and k:
        mlens[k - 1] = 0
        dists[k - 1] = 0
    return lit_src, lit_len, mlens, dists


class _FFRuns:
    """Run-length lookup for 0xFF bytes: how far does the 255-run starting
    at q extend?  Built once from the (few) 255 positions, so extension
    fields resolve with one small searchsorted instead of a scan."""

    def __init__(self, tu: np.ndarray):
        ff = np.flatnonzero(tu == 255)
        self.tu = tu
        self.ff = ff
        if ff.size:
            # remaining run length at each 255 position (groups of
            # consecutive positions, counted from the back of each group)
            grp = np.cumsum(np.concatenate([[0], (np.diff(ff) != 1)]))
            last = np.concatenate([np.flatnonzero(np.diff(grp)), [ff.size - 1]])
            self.rem = ff[last][grp] - ff + 1
        else:
            self.rem = ff

    def ext(self, q: np.ndarray, cap: int):
        """(n ext bytes, decoded value) for extension fields starting at q.

        Values are clipped to ``cap`` (the blob length): anything larger is
        corrupt anyway and the clip keeps later int32 arithmetic exact."""
        if self.ff.size:
            j = np.searchsorted(self.ff, q)
            hit = (j < self.ff.size) & (self.ff[np.minimum(j, self.ff.size - 1)] == q)
            run = np.where(hit, self.rem[np.minimum(j, self.ff.size - 1)], 0)
        else:
            run = np.zeros(q.size, dtype=np.int64)
        end = q + run
        return run + 1, np.minimum(run * 255 + self.tu[end], cap)


def _parse_vector(comp: bytes, base: int, offset_bytes: int):
    """Speculative parse of the dense stream at ``comp[base:]``.
    Returned positions are absolute.  See module docstring."""
    n = len(comp) - base
    pad = 8
    P = n + pad
    tu = np.empty(P, dtype=np.uint8)
    tu[:n] = np.frombuffer(comp, dtype=np.uint8, count=n, offset=base)
    tu[n:] = 0
    qmax = n  # pad bytes are 0 (non-255): every ext query resolves
    lln = tu >> 4
    mln = tu & 15
    # speculative step to the next token, assuming no extension bytes
    step = np.arange(P, dtype=np.int32)
    step += np.int32(1 + offset_bytes)
    step += lln
    mask_ll = lln == 15
    mask_ml = mln == 15
    has_ll_ext = bool(mask_ll.any())
    has_ml_ext = bool(mask_ml.any())
    ffr = None

    def _ffr():
        nonlocal ffr
        if ffr is None:
            ffr = _FFRuns(tu)
        return ffr

    if has_ll_ext:
        pl = np.flatnonzero(mask_ll)
        q = np.minimum(pl + 1, qmax)
        nxt = tu[q]
        # common case: a single extension byte (the next byte ends the run)
        step[pl] += nxt.astype(np.int32) + 1
        rare = np.flatnonzero(nxt == 255)
        if rare.size:
            qr = q[rare]
            nx, val = _ffr().ext(qr, n)
            # remove the speculative single-byte fix, apply the true run
            step[pl[rare]] += (nx + val - 256).astype(np.int32)
    if has_ml_ext:
        pm = np.flatnonzero(mask_ml)
        # step currently points at the first matchlen-ext byte
        q = np.minimum(step[pm], qmax)
        step[pm] += 1
        rare = np.flatnonzero(tu[q] == 255)
        if rare.size:
            nx, _ = _ffr().ext(q[rare], n)
            step[pm[rare]] += (nx - 1).astype(np.int32)
    np.minimum(step, np.int32(n), out=step)  # >= n: sentinel self-loop at n

    s2 = step[step]
    s4 = s2[s2]
    s8 = s4[s4]
    anchors: list[int] = []
    append = anchors.append
    view = memoryview(s8)  # scalar chase: 8 sequences per iteration
    pos = 0
    while pos < n:
        append(pos)
        pos = view[pos]
    if not anchors:
        z = np.zeros(0, dtype=np.int32)
        return z, z.copy(), z.copy(), z.copy()
    a = np.asarray(anchors, dtype=np.int32)
    g2 = s2[a]
    g4 = s4[a]
    g6 = s2[g4]
    tp = np.stack([a, step[a], g2, step[g2], g4, step[g4], g6, step[g6]],
                  axis=1).ravel()
    tp = tp[tp < n]

    # exact fields, gathered at real token positions only
    tok = tu[tp]
    lit_len = (tok >> 4).astype(np.int32)
    lit_src = tp + 1
    mlens = (tok & 15).astype(np.int32) + _MIN_MATCH
    if has_ll_ext:
        el = np.flatnonzero(lit_len == 15)
        if el.size:
            nx, val = _ffr().ext(np.minimum(tp[el] + 1, qmax), n)
            lit_len[el] = (15 + val).astype(np.int32)
            lit_src[el] += nx.astype(np.int32)
    opos = np.minimum(lit_src + lit_len, qmax)
    if has_ml_ext:
        em = np.flatnonzero(tok & 15 == 15)
        if em.size:
            # match lengths are bounded by the OUTPUT size (matches expand),
            # not the comp size — cap only against int32 overflow
            nx, val = _ffr().ext(np.minimum(opos[em] + offset_bytes, qmax),
                                 1 << 30)
            mlens[em] = (15 + _MIN_MATCH + val).astype(np.int32)
    dists = tu[opos].astype(np.int32) | (tu[opos + 1].astype(np.int32) << 8)
    if offset_bytes == 3:
        dists |= tu[opos + 2].astype(np.int32) << 16
    if int(opos[-1]) >= n:  # final sequence is literals-only
        mlens[-1] = 0
        dists[-1] = 0
    if base:
        lit_src += np.int32(base)
    return lit_src, lit_len, mlens, dists


def parse_sequences(comp: bytes, base: int = 0, offset_bytes: int = 2):
    """Parse all sequence headers of ``comp[base:]``.

    Returns ``(lit_src, lit_len, mlens, dists)`` int32 arrays, one row
    per sequence, ``mlens == 0`` marking the final literals-only one."""
    state = _scan_scalar(comp, base, offset_bytes,
                         None if len(comp) - base < _VECTOR_MIN
                         else _PROBE_SEQS)
    if state[4]:  # done
        return _scalar_arrays(comp, state, offset_bytes)
    head = _scalar_arrays(comp, state, offset_bytes)
    tail = _parse_vector(comp, state[3], offset_bytes)
    return tuple(np.concatenate([h, t]) for h, t in zip(head, tail))


# ---------------------------------------------------------------------------
# pass 2: execute
# ---------------------------------------------------------------------------

def _range_concat(starts: np.ndarray, lens: np.ndarray,
                  cs: np.ndarray) -> np.ndarray:
    """Concatenated ``arange(s, s+l)`` for every (start, len) run.

    Equivalent to ``arange(total) + repeat(starts - (cs - lens), lens)``
    but built with one boundary scatter + cumsum — np.repeat loops per run
    in C and is ~5x slower for short runs.  All lens must be > 0 (zero-
    length runs would collide boundary slots)."""
    d = np.ones(int(cs[-1]), dtype=np.int32)
    d[0] = starts[0]
    d[cs[:-1]] = starts[1:] - starts[:-1] - lens[:-1] + 1
    return np.cumsum(d, dtype=np.int32)

def _run_serial(dst: bytearray, mo: np.ndarray, ml: np.ndarray,
                refs: np.ndarray, p: int, q: int) -> None:
    """In-order slice-memcpy replay of matches p..q-1."""
    for o, m, ref in zip(mo[p:q].tolist(), ml[p:q].tolist(),
                         refs[p:q].tolist()):
        if o - ref >= m:   # non-overlapping: one slice copy
            dst[o:o + m] = dst[ref:ref + m]
        else:              # overlapping match: replicate pattern
            while m > 0:
                chunk = min(m, o - ref)
                dst[o:o + chunk] = dst[ref:ref + chunk]
                o += chunk
                m -= chunk


def execute_sequences(comp: bytes, prefix: bytes, orig_len: int,
                      lit_src, lit_len, mlens, dists,
                      name: str = "token stream") -> bytes:
    """Materialize the output of parsed sequences (cumulative-position
    table, vectorized literal placement, batched match replay)."""
    plen = len(prefix)
    k = lit_len.size
    seq_len = lit_len + mlens
    ends = np.cumsum(seq_len, dtype=np.int32)
    decoded = int(ends[-1]) if k else 0
    if decoded != orig_len:
        raise ValueError(f"{name} decoded {decoded} bytes, expected {orig_len}")
    dst = bytearray(plen + orig_len)
    dst[:plen] = prefix
    darr = np.frombuffer(memoryview(dst), dtype=np.uint8)
    lit_dst = plen + ends - seq_len

    total_lit = int(lit_len.sum())
    if total_lit:
        if total_lit > _SCATTER_MAX_RUN * k:
            # few long runs: per-run memcpy beats building index arrays
            for s, l, dp in zip(lit_src.tolist(), lit_len.tolist(),
                                lit_dst.tolist()):
                if l:
                    dst[dp:dp + l] = comp[s:s + l]
        else:
            carr = np.frombuffer(comp, dtype=np.uint8)
            nzr = np.flatnonzero(lit_len)
            ll_ = lit_len[nzr]
            big = np.flatnonzero(ll_ > 1024)
            if big.size:  # dictionary-style head runs: memcpy, not indices
                for j in big.tolist():
                    s, l, dp = (int(lit_src[nzr[j]]), int(ll_[j]),
                                int(lit_dst[nzr[j]]))
                    dst[dp:dp + l] = comp[s:s + l]
                keep = ll_ <= 1024
                nzr = nzr[keep]
                ll_ = ll_[keep]
            if nzr.size:
                cs_ = np.cumsum(ll_)
                darr[_range_concat(lit_dst[nzr], ll_, cs_)] = \
                    carr[_range_concat(lit_src[nzr], ll_, cs_)]

    if k == 0 or int(mlens.max()) == 0:
        return bytes(memoryview(dst)[plen:])

    if k > 1 and mlens[k - 1] == 0 and int(mlens[:k - 1].min()) > 0:
        # dense streams end literals-only with a match everywhere else:
        # plain slices beat a flatnonzero + four gathers
        mo = (lit_dst + lit_len)[:k - 1]
        ml = mlens[:k - 1]
        md = dists[:k - 1]
    else:
        sel = np.flatnonzero(mlens)
        mo = (lit_dst + lit_len)[sel]
        ml = mlens[sel]
        md = dists[sel]
    refs = mo - md
    if int(md.min()) < 1 or int(refs.min()) < 0:
        raise ValueError(f"{name} match offset reaches before the window")
    K = mo.size
    ov = np.flatnonzero(md < ml)          # overlapping: batch-breakers
    if K < 2 * _BATCH_MIN or K // (ov.size + 1) < _BATCH_MIN:
        # close-referencing regime: batches would be tiny, stay serial
        _run_serial(dst, mo, ml, refs, 0, K)
        return bytes(memoryview(dst)[plen:])

    # global gather indices: a batch is two numpy calls over a slice
    re = refs + ml
    cs = np.cumsum(ml)
    pre = cs - ml
    didx = _range_concat(mo, ml, cs)
    sidx = _range_concat(refs, ml, cs)
    bounds = ov.tolist() + [K]
    s0 = 0
    for b in bounds:
        if b - s0 >= _BATCH_MIN:
            # segment without overlap matches: re <= o elementwise, so the
            # first conflict for frontier o[p] is exactly where the running
            # max of re exceeds it
            M = np.maximum.accumulate(re[s0:b])
            Q = np.searchsorted(M, mo[s0:b], side="right")
            p = s0
            while p < b:
                q = int(Q[p - s0]) + s0  # > p: re[p] <= o[p] holds here
                if q - p >= _BATCH_MIN:
                    sl = slice(int(pre[p]), int(cs[q - 1]))
                    darr[didx[sl]] = darr[sidx[sl]]
                else:
                    _run_serial(dst, mo, ml, refs, p, q)
                p = q
        elif b > s0:
            _run_serial(dst, mo, ml, refs, s0, b)
        if b < K:  # the overlap match itself
            _run_serial(dst, mo, ml, refs, b, b + 1)
        s0 = b + 1
    return bytes(memoryview(dst)[plen:])


# ---------------------------------------------------------------------------
# entry point
# ---------------------------------------------------------------------------

def decode_token_stream(comp: bytes, prefix: bytes, orig_len: int,
                        base: int = 0, offset_bytes: int = 2,
                        name: str = "token stream") -> bytes:
    """Decode an LZ4-framed token stream, routing by sequence density."""
    if len(comp) - base < _VECTOR_MIN:
        return _decode_serial(comp, prefix, orig_len, base, offset_bytes, name)
    if comp[base] >> 4 == 15 and comp[base + 1:base + 257] == b"\xff" * 256:
        # >= 64 KiB leading literal (incompressible payload): go serial now
        # rather than walking the extension run in the probe and again here
        return _decode_serial(comp, prefix, orig_len, base, offset_bytes, name)
    state = _scan_scalar(comp, base, offset_bytes, _PROBE_SEQS)
    if state[4]:  # whole stream fits in the probe: too few sequences
        return _decode_serial(comp, prefix, orig_len, base, offset_bytes, name)
    head = _scalar_arrays(comp, state, offset_bytes)
    # density estimate, discounting one dictionary-style leading literal
    scanned = state[3] - base - int(head[1].max())
    if scanned >= _SERIAL_DENSITY * max(len(state[0]) - 1, 1):
        # long sequences: the serial decoder is memcpy-bound already
        return _decode_serial(comp, prefix, orig_len, base, offset_bytes, name)
    tail = _parse_vector(comp, state[3], offset_bytes)
    arrays = tuple(np.concatenate([h, t]) for h, t in zip(head, tail))
    return execute_sequences(comp, prefix, orig_len, *arrays, name=name)
