"""Checksums: naive vs vectorized adler32/crc32.

Reproduces the CF-ZLIB mechanism from the paper's §2.1:

* adler32 hotspot: CF-ZLIB uses ``_mm_sad_epu8`` (SSE byte sum-of-absolute-
  differences) to sum bytes 16-at-a-time and shuffle-adds to accumulate the
  position-weighted term.  The numpy analogue below does exactly the same
  algebra — block byte-sums for ``A`` and a weighted prefix formulation for
  ``B`` — trading the per-byte serial loop for wide vector reductions.
* crc32 hotspot: hardware ``crc32`` instructions vs table lookup.  We expose
  three tiers: ``crc32_naive`` (bitwise, the 1995-style loop),
  ``crc32_table`` (byte-at-a-time table — classic software), and
  ``crc32_slice8`` (vectorized slice-by-8 over numpy — the "hardware
  assisted" stand-in; on CPython the true hardware path is
  ``zlib.crc32``, also exposed for the benchmark's top tier).

The benchmark in ``benchmarks/fig45_cfzlib.py`` measures these tiers and
reproduces the structure of the paper's Figures 4–5.

All implementations agree bit-exactly with ``zlib.adler32``/``zlib.crc32``.
"""

from __future__ import annotations

import zlib

import numpy as np

__all__ = [
    "adler32_naive",
    "adler32_vector",
    "adler32_hw",
    "crc32_naive",
    "crc32_table",
    "crc32_slice8",
    "crc32_hw",
]

_MOD = 65521  # largest prime < 2^16


# ---------------------------------------------------------------------------
# adler32
# ---------------------------------------------------------------------------

def adler32_naive(data: bytes, value: int = 1) -> int:
    """Reference per-byte loop (the pre-CF hot spot)."""
    a = value & 0xFFFF
    b = (value >> 16) & 0xFFFF
    for byte in data:
        a = (a + byte) % _MOD
        b = (b + a) % _MOD
    return (b << 16) | a


def adler32_vector(data: bytes, value: int = 1, block: int = 1 << 16) -> int:
    """Vectorized adler32 — the ``_mm_sad_epu8`` trick in numpy.

    For a block of n bytes x_0..x_{n-1} starting from state (a, b):
        a' = a + sum(x)
        b' = b + n*a + sum((n - i) * x_i)
    Both sums are wide vector reductions; the weighted sum is the numpy
    equivalent of CF-ZLIB's shuffle-add accumulation of SAD partial sums.
    Blocks are sized so int64 accumulators cannot overflow.
    """
    a = value & 0xFFFF
    b = (value >> 16) & 0xFFFF
    arr = np.frombuffer(data, dtype=np.uint8)
    n_total = arr.size
    for off in range(0, n_total, block):
        x = arr[off:off + block].astype(np.int64)
        n = x.size
        s = int(x.sum())
        w = int((np.arange(n, 0, -1, dtype=np.int64) * x).sum())
        b = (b + n * a + w) % _MOD
        a = (a + s) % _MOD
    return (b << 16) | a


def adler32_hw(data: bytes, value: int = 1) -> int:
    """zlib's C implementation — the 'shipped library' tier."""
    return zlib.adler32(data, value) & 0xFFFFFFFF


# ---------------------------------------------------------------------------
# crc32 (IEEE 802.3 polynomial, reflected: 0xEDB88320)
# ---------------------------------------------------------------------------

_POLY = 0xEDB88320


def _make_table(n_slices: int = 8) -> np.ndarray:
    tab = np.zeros((n_slices, 256), dtype=np.uint32)
    for i in range(256):
        c = i
        for _ in range(8):
            c = (c >> 1) ^ (_POLY if c & 1 else 0)
        tab[0, i] = c
    for s in range(1, n_slices):
        for i in range(256):
            c = tab[s - 1, i]
            tab[s, i] = (c >> 8) ^ tab[0, c & 0xFF]
    return tab


_TABLE = _make_table(8)
_T0 = [int(x) for x in _TABLE[0]]


def crc32_naive(data: bytes, value: int = 0) -> int:
    """Bitwise loop — the unaccelerated tier ("no hardware crc32")."""
    crc = (value ^ 0xFFFFFFFF) & 0xFFFFFFFF
    for byte in data:
        crc ^= byte
        for _ in range(8):
            crc = (crc >> 1) ^ (_POLY if crc & 1 else 0)
    return crc ^ 0xFFFFFFFF


def crc32_table(data: bytes, value: int = 0) -> int:
    """Byte-at-a-time table lookup — classic software crc32."""
    crc = (value ^ 0xFFFFFFFF) & 0xFFFFFFFF
    for byte in data:
        crc = (crc >> 8) ^ _T0[(crc ^ byte) & 0xFF]
    return crc ^ 0xFFFFFFFF


def crc32_slice8(data: bytes, value: int = 0) -> int:
    """Slice-by-8: processes 8 bytes per step with table-parallel lookups.

    This is the software analogue of the hardware-crc32 path: the inner
    dependency chain is per-8-bytes instead of per-byte, and the eight
    table lookups vectorize.  (numpy gathers make the lookups wide; the
    chain over 8-byte words remains, as it does on real slice-by-8.)
    """
    crc = (value ^ 0xFFFFFFFF) & 0xFFFFFFFF
    arr = np.frombuffer(data, dtype=np.uint8)
    n8 = (arr.size // 8) * 8
    words = arr[:n8].reshape(-1, 8)
    t = _TABLE
    for row in words:
        x = crc ^ int(row[0]) ^ (int(row[1]) << 8) ^ (int(row[2]) << 16) ^ (int(row[3]) << 24)
        crc = (
            int(t[7, x & 0xFF])
            ^ int(t[6, (x >> 8) & 0xFF])
            ^ int(t[5, (x >> 16) & 0xFF])
            ^ int(t[4, (x >> 24) & 0xFF])
            ^ int(t[3, int(row[4])])
            ^ int(t[2, int(row[5])])
            ^ int(t[1, int(row[6])])
            ^ int(t[0, int(row[7])])
        )
    for byte in arr[n8:]:
        crc = (crc >> 8) ^ _T0[(crc ^ int(byte)) & 0xFF]
    return crc ^ 0xFFFFFFFF


def crc32_hw(data: bytes, value: int = 0) -> int:
    """zlib's C crc32 — the hardware/asm tier on this host."""
    return zlib.crc32(data, value) & 0xFFFFFFFF
