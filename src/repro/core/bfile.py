"""BasketFile: the on-disk container (the "ROOT file" of this framework).

Layout::

    [8B magic "RBKTv001"][baskets...][TOC json][8B TOC length][8B magic]

* The TOC (table of contents) maps branch name -> dtype/shape/compression
  config/dictionary + the (offset, length, meta) of every basket — ROOT's
  directory/streamer-info analogue, minus C++ streamers.
* Baskets are written streaming; the TOC goes last, and the file is written
  to a temp path then atomically renamed — a crash mid-write can never
  produce a file with a valid trailer (fault-tolerance invariant used by
  the checkpointer).
* Dictionaries (paper §2.3 "placement within the ROOT file" open question):
  stored once in the TOC region per branch, not per basket — amortizing
  dictionary bytes across baskets, which is the sizing/placement policy the
  paper asks for (evaluated in benchmarks/fig_dict.py).
"""

from __future__ import annotations

import base64
import itertools
import json
import os
import threading
from concurrent.futures import ThreadPoolExecutor
from typing import Optional

import numpy as np

from .basket import (BasketMeta, byte_offsets, join_baskets, split_array,
                     unpack_basket, unpack_basket_into)
from .codec import CompressionConfig


def _pread(path: str, offset: int, n: int, expect=None) -> bytes:
    # lazy import: repro.io imports repro.core at package-init time
    from repro.io import fdcache
    return fdcache.pread(path, offset, n, expect=expect)

__all__ = ["BasketWriter", "BasketFile", "write_arrays", "read_arrays"]

_MAGIC = b"RBKTv001"


class BasketWriter:
    """Streaming writer with atomic commit.

    ``workers>0`` (or an explicit shared ``engine``) turns on the parallel
    I/O engine (repro.io.engine): baskets compress concurrently on a
    bounded pool while this thread commits payloads in offset order —
    output is byte-identical to the serial path.
    """

    def __init__(self, path: str, workers: int = 0, engine=None,
                 tuner=None, objective=None):
        self.path = str(path)
        self._tmp = self.path + ".tmp"
        os.makedirs(os.path.dirname(os.path.abspath(self.path)), exist_ok=True)
        self._f = open(self._tmp, "wb")
        self._f.write(_MAGIC)
        self._branches: dict[str, dict] = {}
        self._closed = False
        self._engine = engine
        self._owns_engine = False
        if engine is None and workers:
            from repro.io.engine import CompressionEngine
            self._engine = CompressionEngine(workers)
            self._owns_engine = True
        # adaptive codec selection (repro.tune): branches written without
        # an explicit cfg are tuned per-branch; decisions persist in the
        # TOC so re-opens/appends reuse them without re-measurement
        if tuner is None and objective is not None:
            from repro.tune import Tuner
            tuner = Tuner(objective, engine=self._engine)
        self._tuner = tuner

    def write_branch(self, name: str, arr: np.ndarray,
                     cfg: Optional[CompressionConfig] = None,
                     target_basket_bytes: int = 1 << 20) -> dict:
        """Serialize an array column-wise into compressed baskets.

        With a tuner attached and no explicit ``cfg``, the config is the
        tuner's per-branch decision, measured here on stratified windows
        of the *whole* array (cached decisions are reused)."""
        arr = np.asarray(arr)
        if cfg is None and self._tuner is not None:
            cfg = self._tuner.config_for(name, arr)
        return self.write_branch_chunks(
            name, dtype=arr.dtype.str, shape=arr.shape,
            chunks=split_array(arr, target_basket_bytes), cfg=cfg)

    def write_branch_chunks(self, name: str, *, dtype, shape, chunks,
                            cfg: Optional[CompressionConfig] = None) -> dict:
        """Stream a branch from a ``(entry_start, entry_count, buffer)``
        chunk iterator without materializing the whole array — the
        checkpointer's device→host staging path.  Chunk boundaries are the
        caller's; to match :func:`write_branch` bytes exactly, produce
        the boundaries of :func:`repro.core.basket.basket_rows`."""
        if name in self._branches:
            raise ValueError(f"branch {name!r} already written")
        if cfg is None and self._tuner is not None:
            # streaming path: the tuner probes the first chunk (the only
            # data available without materializing the branch)
            it = iter(chunks)
            first = next(it, None)
            if first is not None:
                cfg = self._tuner.config_for(
                    name, first[2], dtype=np.dtype(dtype))
                chunks = itertools.chain([first], it)
        cfg = cfg or CompressionConfig()
        engine = self._engine
        if engine is None:
            from repro.io.engine import CompressionEngine
            engine = CompressionEngine(0)   # the serial path — no pools
        packed = engine.pack_stream(chunks, cfg)
        baskets = []
        for _start, _count, payload, meta in packed:
            off = self._f.tell()
            self._f.write(payload)   # accepts memoryview payloads zero-copy
            if self._tuner is not None:
                self._tuner.observe(name, meta)     # drift-detector feed
            baskets.append({"offset": off, "meta": meta.to_json()})
        entry = {
            "dtype": np.dtype(dtype).str,
            "shape": list(shape),
            "config": {"algo": cfg.algo, "level": cfg.level, "precond": cfg.precond},
            "dictionary": base64.b64encode(cfg.dictionary).decode() if cfg.dictionary else None,
            "baskets": baskets,
        }
        self._branches[name] = entry
        return entry

    def write_precompressed(self, name: str, *, dtype, shape, config,
                            dictionary, baskets) -> dict:
        """Append already-compressed ``(payload, meta_json)`` baskets as a
        branch — the BufferMerger/fast-merge path (no recompression)."""
        if name in self._branches:
            raise ValueError(f"branch {name!r} already written")
        out = []
        for payload, meta_json in baskets:
            off = self._f.tell()
            self._f.write(payload)
            out.append({"offset": off, "meta": dict(meta_json)})
        entry = {"dtype": dtype, "shape": list(shape), "config": dict(config),
                 "dictionary": dictionary, "baskets": out}
        self._branches[name] = entry
        return entry

    def write_blob(self, name: str, raw: bytes, cfg: Optional[CompressionConfig] = None) -> None:
        """Opaque byte branch (metadata blobs, tokenizer state, ...)."""
        self.write_branch(name, np.frombuffer(raw, dtype=np.uint8), cfg)

    def close(self) -> None:
        if self._closed:
            return
        doc = {"branches": self._branches}
        if self._tuner is not None:
            # persist this file's tuning decisions in the header so appends
            # and re-opens (Tuner.from_file / load_decisions) reuse them
            # without re-measurement; decisions for branches not written
            # here are not this file's to record
            tuned = self._tuner.decisions_json(names=self._branches)
            if tuned:
                doc["tuning"] = tuned
        toc = json.dumps(doc).encode()
        self._f.write(toc)
        self._f.write(len(toc).to_bytes(8, "little"))
        self._f.write(_MAGIC)
        self._f.flush()
        os.fsync(self._f.fileno())
        self._f.close()
        os.replace(self._tmp, self.path)  # atomic commit
        self._closed = True
        if self._owns_engine:
            self._engine.close()

    def abort(self) -> None:
        if not self._closed:
            self._f.close()
            if os.path.exists(self._tmp):
                os.remove(self._tmp)
            self._closed = True
            if self._owns_engine:
                self._engine.close()

    def __enter__(self):
        return self

    def __exit__(self, exc_type, *a):
        if exc_type is None:
            self.close()
        else:
            self.abort()


class BasketFile:
    """Reader with optional thread-pool parallel decompression.

    ``workers``/``prefetch`` delegate reads to the parallel I/O engine:
    ``workers`` sets the default decompression pool width, ``prefetch>0``
    routes ``read_branch``/``read_entries`` through a decompress-ahead
    :class:`repro.io.prefetch.PrefetchReader` (``prefetch`` = read-ahead
    depth in baskets) with an LRU decompressed-basket cache.
    """

    def __init__(self, path: str, verify: bool = True,
                 workers: int = 0, prefetch: int = 0):
        self.path = str(path)
        self.verify = verify
        self.workers = workers
        self.prefetch = prefetch
        self._engine = None
        self._readers: dict = {}
        self._reader_lock = threading.Lock()
        self._closed = False
        with open(self.path, "rb") as f:
            # the generation of the inode whose TOC we are about to read:
            # every later pread checks against it, so a tmp-then-replace of
            # the path raises StaleFileError instead of slicing baskets out
            # of a file this TOC does not describe
            st = os.fstat(f.fileno())
            self.generation = (st.st_dev, st.st_ino)
            head = f.read(8)
            if head != _MAGIC:
                raise ValueError(f"{path}: not a BasketFile (bad magic)")
            f.seek(-16, os.SEEK_END)
            toc_len = int.from_bytes(f.read(8), "little")
            if f.read(8) != _MAGIC:
                raise ValueError(f"{path}: truncated (bad trailer) — incomplete write?")
            f.seek(-16 - toc_len, os.SEEK_END)
            self._toc = json.loads(f.read(toc_len))
        self.branches = self._toc["branches"]
        # per-branch autotuner decisions persisted at write time (may be
        # absent: files predating repro.tune, or written without a tuner)
        self.tuning = self._toc.get("tuning", {})

    def branch_names(self) -> list[str]:
        return list(self.branches)

    def tuning_decisions(self) -> dict[str, dict]:
        """Persisted per-branch tuner decisions (``{}`` when untuned) —
        feed to :meth:`repro.tune.Tuner.load` to append/re-open without
        re-measurement."""
        return dict(self.tuning)

    def _dictionary(self, entry: dict) -> Optional[bytes]:
        d = entry.get("dictionary")
        return base64.b64decode(d) if d else None

    def read_basket_payload(self, name: str, i: int) -> bytes:
        """Compressed on-disk payload of one basket (no decompression) —
        the fast-merge path."""
        entry = self.branches[name]
        b = entry["baskets"][i]
        return _pread(self.path, b["offset"], b["meta"]["comp_len"],
                      expect=self.generation)

    def read_basket_raw(self, name: str, i: int) -> bytes:
        entry = self.branches[name]
        b = entry["baskets"][i]
        meta = BasketMeta.from_json(b["meta"])
        payload = _pread(self.path, b["offset"], meta.comp_len,
                         expect=self.generation)
        return unpack_basket(payload, meta, self._dictionary(entry), verify=self.verify)

    def read_basket_into(self, name: str, i: int, out) -> int:
        """Read + decode basket ``i`` directly into ``out`` (writable
        buffer ≥ ``orig_len`` bytes) — the zero-copy scatter step."""
        entry = self.branches[name]
        b = entry["baskets"][i]
        meta = BasketMeta.from_json(b["meta"])
        payload = _pread(self.path, b["offset"], meta.comp_len,
                         expect=self.generation)
        return unpack_basket_into(payload, meta, out, self._dictionary(entry),
                                  verify=self.verify)

    def _reader(self, name: str):
        """Cached PrefetchReader per branch (engine shared across them);
        locked — one BasketFile may serve readers on several threads."""
        with self._reader_lock:
            if name not in self._readers:
                from repro.io.engine import CompressionEngine
                from repro.io.prefetch import PrefetchReader
                if self._engine is None:
                    self._engine = CompressionEngine(self.workers or 2)
                self._readers[name] = PrefetchReader(
                    self, name, ahead=self.prefetch, engine=self._engine)
            return self._readers[name]

    @staticmethod
    def _byte_offsets(entry: dict) -> tuple[list[int], int]:
        return byte_offsets(b["meta"]["orig_len"] for b in entry["baskets"])

    def read_branch(self, name: str, workers: Optional[int] = None) -> np.ndarray:
        """Read + decompress a branch; ``workers>0`` = parallel decompression
        (the paper's simultaneous-read-and-decompress).

        Zero-copy plane: the destination array is allocated once and every
        basket decodes directly into its slice — no per-basket ``bytes``,
        no final concatenation."""
        if workers is None:
            workers = self.workers
        if self.prefetch:
            return self._reader(name).read_all()
        entry = self.branches[name]
        n = len(entry["baskets"])
        out = np.empty(tuple(entry["shape"]), dtype=np.dtype(entry["dtype"]))
        offs, total = self._byte_offsets(entry)
        if total != out.nbytes:
            # malformed TOC: fall back to the copying join (raises there)
            chunks = [self.read_basket_raw(name, i) for i in range(n)]
            return join_baskets(chunks, entry["dtype"], tuple(entry["shape"]))
        flat = out.reshape(-1).view(np.uint8)

        def scatter(i: int) -> None:
            ln = entry["baskets"][i]["meta"]["orig_len"]
            self.read_basket_into(name, i, flat[offs[i]:offs[i] + ln])

        if workers and n > 1:
            with ThreadPoolExecutor(max_workers=workers) as ex:
                list(ex.map(scatter, range(n)))
        else:
            for i in range(n):
                scatter(i)
        return out

    def read_entries(self, name: str, start: int, stop: int) -> np.ndarray:
        """Row-range read touching only the covering baskets (seekability).
        With ``prefetch>0`` the decompress-ahead reader also schedules the
        baskets *after* the range, hiding latency for forward scans."""
        if self.prefetch:
            return self._reader(name).read_entries(start, stop)
        entry = self.branches[name]
        shape = tuple(entry["shape"])
        dtype = np.dtype(entry["dtype"])
        cover, first_entry, total = [], None, 0
        for i, b in enumerate(entry["baskets"]):
            m = b["meta"]
            if m["entry_start"] + m["entry_count"] <= start or m["entry_start"] >= stop:
                continue
            if first_entry is None:
                first_entry = m["entry_start"]
            cover.append((i, total, m["orig_len"]))
            total += m["orig_len"]
        if not cover:
            return np.zeros((0,) + shape[1:], dtype=dtype)
        row_elems = int(np.prod(shape[1:], dtype=np.int64)) or 1
        rows = total // (dtype.itemsize * row_elems)
        arr = np.empty((rows,) + shape[1:], dtype=dtype)
        flat = arr.reshape(-1).view(np.uint8)
        for i, off, ln in cover:
            self.read_basket_into(name, i, flat[off:off + ln])
        return arr[start - first_entry: stop - first_entry].copy()

    def compressed_bytes(self, name: Optional[str] = None) -> int:
        names = [name] if name else self.branch_names()
        return sum(b["meta"]["comp_len"] for n in names for b in self.branches[n]["baskets"])

    def raw_bytes(self, name: Optional[str] = None) -> int:
        names = [name] if name else self.branch_names()
        return sum(b["meta"]["orig_len"] for n in names for b in self.branches[n]["baskets"])

    def compression_ratio(self, name: Optional[str] = None) -> float:
        c = self.compressed_bytes(name)
        return self.raw_bytes(name) / c if c else float("inf")

    def close(self) -> None:
        """Release prefetch readers, the engine pool, and this path's
        cached fd (so a long-lived server doesn't pin unlinked inodes
        until LRU eviction).  Idempotent: a second close is a no-op."""
        with self._reader_lock:
            if self._closed:
                return
            self._closed = True
            readers, self._readers = list(self._readers.values()), {}
            engine, self._engine = self._engine, None
        for r in readers:
            r.close()
        if engine is not None:
            engine.close()
        from repro.io import fdcache
        fdcache.invalidate(self.path)

    def __enter__(self):
        return self

    def __exit__(self, *a):
        self.close()


# ---------------------------------------------------------------------------
# pytree-of-arrays convenience (used by the checkpointer)
# ---------------------------------------------------------------------------

def write_arrays(path: str, arrays: dict[str, np.ndarray],
                 cfg_for: Optional[callable] = None,
                 target_basket_bytes: int = 1 << 20,
                 workers: int = 0, tuner=None, objective=None) -> None:
    """Write a flat dict of named arrays; ``cfg_for(name, arr)`` picks the
    per-branch CompressionConfig (the codec policy hook); ``workers>0``
    compresses baskets in parallel (identical bytes).  ``tuner=`` /
    ``objective=`` switch branches without an explicit config to
    measurement-driven selection (repro.tune)."""
    with BasketWriter(path, workers=workers, tuner=tuner,
                      objective=objective) as w:
        for name, arr in arrays.items():
            cfg = cfg_for(name, np.asarray(arr)) if cfg_for else None
            w.write_branch(name, arr, cfg, target_basket_bytes)


def read_arrays(path: str, workers: int = 0, prefetch: int = 0) -> dict[str, np.ndarray]:
    with BasketFile(path, workers=workers, prefetch=prefetch) as f:
        return {name: f.read_branch(name, workers=workers)
                for name in f.branch_names()}
