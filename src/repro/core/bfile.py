"""BasketFile: the on-disk container (the "ROOT file" of this framework).

Layout::

    [8B magic "RBKTv001"][baskets...][TOC json][8B TOC length][8B magic]

* The TOC (table of contents) maps branch name -> dtype/shape/compression
  config/dictionary + the (offset, length, meta) of every basket — ROOT's
  directory/streamer-info analogue, minus C++ streamers.
* Baskets are written streaming; the TOC goes last, and the file is written
  to a temp path then atomically renamed — a crash mid-write can never
  produce a file with a valid trailer (fault-tolerance invariant used by
  the checkpointer).
* Dictionaries (paper §2.3 "placement within the ROOT file" open question):
  stored once in the TOC region per branch, not per basket — amortizing
  dictionary bytes across baskets, which is the sizing/placement policy the
  paper asks for (evaluated in benchmarks/fig_dict.py).
"""

from __future__ import annotations

import base64
import json
import os
from concurrent.futures import ThreadPoolExecutor
from typing import Optional

import numpy as np

from .basket import BasketMeta, join_baskets, pack_basket, split_array, unpack_basket
from .codec import CompressionConfig

__all__ = ["BasketWriter", "BasketFile", "write_arrays", "read_arrays"]

_MAGIC = b"RBKTv001"


class BasketWriter:
    """Streaming writer with atomic commit."""

    def __init__(self, path: str):
        self.path = str(path)
        self._tmp = self.path + ".tmp"
        os.makedirs(os.path.dirname(os.path.abspath(self.path)), exist_ok=True)
        self._f = open(self._tmp, "wb")
        self._f.write(_MAGIC)
        self._branches: dict[str, dict] = {}
        self._closed = False

    def write_branch(self, name: str, arr: np.ndarray,
                     cfg: Optional[CompressionConfig] = None,
                     target_basket_bytes: int = 1 << 20) -> dict:
        """Serialize an array column-wise into compressed baskets."""
        if name in self._branches:
            raise ValueError(f"branch {name!r} already written")
        cfg = cfg or CompressionConfig()
        arr = np.asarray(arr)
        baskets = []
        for start, count, raw in split_array(arr, target_basket_bytes):
            payload, meta = pack_basket(raw, cfg, entry_start=start, entry_count=count)
            off = self._f.tell()
            self._f.write(payload)
            baskets.append({"offset": off, "meta": meta.to_json()})
        entry = {
            "dtype": arr.dtype.str,
            "shape": list(arr.shape),
            "config": {"algo": cfg.algo, "level": cfg.level, "precond": cfg.precond},
            "dictionary": base64.b64encode(cfg.dictionary).decode() if cfg.dictionary else None,
            "baskets": baskets,
        }
        self._branches[name] = entry
        return entry

    def write_blob(self, name: str, raw: bytes, cfg: Optional[CompressionConfig] = None) -> None:
        """Opaque byte branch (metadata blobs, tokenizer state, ...)."""
        self.write_branch(name, np.frombuffer(raw, dtype=np.uint8), cfg)

    def close(self) -> None:
        if self._closed:
            return
        toc = json.dumps({"branches": self._branches}).encode()
        self._f.write(toc)
        self._f.write(len(toc).to_bytes(8, "little"))
        self._f.write(_MAGIC)
        self._f.flush()
        os.fsync(self._f.fileno())
        self._f.close()
        os.replace(self._tmp, self.path)  # atomic commit
        self._closed = True

    def abort(self) -> None:
        if not self._closed:
            self._f.close()
            if os.path.exists(self._tmp):
                os.remove(self._tmp)
            self._closed = True

    def __enter__(self):
        return self

    def __exit__(self, exc_type, *a):
        if exc_type is None:
            self.close()
        else:
            self.abort()


class BasketFile:
    """Reader with optional thread-pool parallel decompression."""

    def __init__(self, path: str, verify: bool = True):
        self.path = str(path)
        self.verify = verify
        with open(self.path, "rb") as f:
            head = f.read(8)
            if head != _MAGIC:
                raise ValueError(f"{path}: not a BasketFile (bad magic)")
            f.seek(-16, os.SEEK_END)
            toc_len = int.from_bytes(f.read(8), "little")
            if f.read(8) != _MAGIC:
                raise ValueError(f"{path}: truncated (bad trailer) — incomplete write?")
            f.seek(-16 - toc_len, os.SEEK_END)
            self._toc = json.loads(f.read(toc_len))
        self.branches = self._toc["branches"]

    def branch_names(self) -> list[str]:
        return list(self.branches)

    def _dictionary(self, entry: dict) -> Optional[bytes]:
        d = entry.get("dictionary")
        return base64.b64decode(d) if d else None

    def read_basket_raw(self, name: str, i: int) -> bytes:
        entry = self.branches[name]
        b = entry["baskets"][i]
        meta = BasketMeta.from_json(b["meta"])
        with open(self.path, "rb") as f:
            f.seek(b["offset"])
            payload = f.read(meta.comp_len)
        return unpack_basket(payload, meta, self._dictionary(entry), verify=self.verify)

    def read_branch(self, name: str, workers: int = 0) -> np.ndarray:
        """Read + decompress a branch; ``workers>0`` = parallel decompression
        (the paper's simultaneous-read-and-decompress)."""
        entry = self.branches[name]
        n = len(entry["baskets"])
        if workers and n > 1:
            with ThreadPoolExecutor(max_workers=workers) as ex:
                chunks = list(ex.map(lambda i: self.read_basket_raw(name, i), range(n)))
        else:
            chunks = [self.read_basket_raw(name, i) for i in range(n)]
        return join_baskets(chunks, entry["dtype"], tuple(entry["shape"]))

    def read_entries(self, name: str, start: int, stop: int) -> np.ndarray:
        """Row-range read touching only the covering baskets (seekability)."""
        entry = self.branches[name]
        shape = tuple(entry["shape"])
        chunks, first_entry = [], None
        for i, b in enumerate(entry["baskets"]):
            m = BasketMeta.from_json(b["meta"])
            if m.entry_start + m.entry_count <= start or m.entry_start >= stop:
                continue
            if first_entry is None:
                first_entry = m.entry_start
            chunks.append(self.read_basket_raw(name, i))
        if not chunks:
            return np.zeros((0,) + shape[1:], dtype=np.dtype(entry["dtype"]))
        buf = b"".join(chunks)
        rows = len(buf) // (np.dtype(entry["dtype"]).itemsize * int(np.prod(shape[1:], dtype=np.int64)) or 1)
        arr = np.frombuffer(buf, dtype=np.dtype(entry["dtype"])).reshape((rows,) + shape[1:])
        return arr[start - first_entry: stop - first_entry].copy()

    def compressed_bytes(self, name: Optional[str] = None) -> int:
        names = [name] if name else self.branch_names()
        return sum(b["meta"]["comp_len"] for n in names for b in self.branches[n]["baskets"])

    def raw_bytes(self, name: Optional[str] = None) -> int:
        names = [name] if name else self.branch_names()
        return sum(b["meta"]["orig_len"] for n in names for b in self.branches[n]["baskets"])

    def compression_ratio(self, name: Optional[str] = None) -> float:
        c = self.compressed_bytes(name)
        return self.raw_bytes(name) / c if c else float("inf")


# ---------------------------------------------------------------------------
# pytree-of-arrays convenience (used by the checkpointer)
# ---------------------------------------------------------------------------

def write_arrays(path: str, arrays: dict[str, np.ndarray],
                 cfg_for: Optional[callable] = None,
                 target_basket_bytes: int = 1 << 20) -> None:
    """Write a flat dict of named arrays; ``cfg_for(name, arr)`` picks the
    per-branch CompressionConfig (the codec policy hook)."""
    with BasketWriter(path) as w:
        for name, arr in arrays.items():
            cfg = cfg_for(name, np.asarray(arr)) if cfg_for else None
            w.write_branch(name, arr, cfg, target_basket_bytes)


def read_arrays(path: str, workers: int = 0) -> dict[str, np.ndarray]:
    f = BasketFile(path)
    return {name: f.read_branch(name, workers=workers) for name in f.branch_names()}
