"""BasketFile: the on-disk container (the "ROOT file" of this framework).

Layout::

    [8B magic "RBKTv001"][baskets...][TOC json][8B TOC length][8B magic]

* The TOC (table of contents) maps branch name -> dtype/shape/compression
  config/dictionary + the (offset, length, meta) of every basket — ROOT's
  directory/streamer-info analogue, minus C++ streamers.
* Baskets are written streaming; the TOC goes last, and the file is written
  to a temp path then atomically renamed — a crash mid-write can never
  produce a file with a valid trailer (fault-tolerance invariant used by
  the checkpointer).
* Dictionaries (paper §2.3 "placement within the ROOT file" open question):
  stored once in the TOC region per branch, not per basket — amortizing
  dictionary bytes across baskets, which is the sizing/placement policy the
  paper asks for (evaluated in benchmarks/fig_dict.py).
"""

from __future__ import annotations

import base64
import itertools
import json
import lzma
import os
import threading
import zlib
from concurrent.futures import ThreadPoolExecutor
from typing import Optional

import numpy as np

from .basket import (BasketMeta, ChecksumError, byte_offsets, join_baskets,
                     split_array, unpack_basket, unpack_basket_into)
from .checksum import adler32_hw
from .codec import CompressionConfig


def _pread(path: str, offset: int, n: int, expect=None) -> bytes:
    # lazy import: repro.io imports repro.core at package-init time
    from repro.io import fdcache
    return fdcache.pread(path, offset, n, expect=expect)

__all__ = ["BasketWriter", "BasketFile", "write_arrays", "read_arrays",
           "CorruptBasketError", "TruncatedContainerError",
           "recover_container"]

_MAGIC = b"RBKTv001"
_JOURNAL_MAGIC = "RBKJ1"

# Everything a damaged payload can raise out of the decode path: adler /
# shape mismatches (ValueError, incl. ChecksumError), malformed metadata
# (KeyError), torn preads (EOFError), a garbled *compressed* stream blowing
# up inside a codec before the adler check runs (zlib.error / LZMAError /
# IndexError from the pure-Python LZ4 match copier).  Staleness (OSError)
# is deliberately absent — a replaced file must never be "healed".
_DECODE_ERRORS = (ValueError, KeyError, IndexError, EOFError,
                  zlib.error, lzma.LZMAError)


class CorruptBasketError(ChecksumError):
    """A basket's decoded bytes fail their stored adler32 — structured:
    names the container, branch, basket index, and byte offset so the
    operator (or a repair tool) can locate the damage without a hexdump."""

    def __init__(self, path: str, branch: str, index: int, offset: int,
                 cause=None):
        super().__init__(
            f"corrupt basket in {path}: branch={branch!r} index={index} "
            f"offset={offset}" + (f" ({cause})" if cause else ""))
        self.path = str(path)
        self.branch = str(branch)
        self.index = int(index)
        self.offset = int(offset)


class TruncatedContainerError(ValueError):
    """The container is torn or truncated (crash mid-copy, partial
    download, disk-full tail loss): header present but the TOC trailer is
    missing or inconsistent.  :func:`recover_container` can salvage every
    basket that precedes the tear when a write journal is present."""

    def __init__(self, path: str, msg: str):
        super().__init__(f"{path}: {msg}")
        self.path = str(path)


def _fsync_dir(dirname: str) -> None:
    """fsync the directory so a rename survives a power cut — the commit
    is not durable until the directory entry itself is on disk."""
    try:
        dfd = os.open(dirname or ".", os.O_RDONLY)
    except OSError:
        return                       # not fsyncable here (e.g. some FSes)
    try:
        os.fsync(dfd)
    except OSError:
        pass
    finally:
        os.close(dfd)


def _journal_path(path: str) -> str:
    """The write journal that describes ``path``'s bytes.  A leftover
    ``*.tmp`` from a crashed writer shares its final path's journal (the
    tmp is byte-for-byte the committed prefix)."""
    path = str(path)
    if path.endswith(".tmp"):
        path = path[:-4]
    return path + ".journal"


def _count_corrupt() -> None:
    try:
        from repro import obs
        obs.counter("bfile.corrupt_baskets").inc()
    except Exception:
        pass


def _count_repair(event: str) -> None:
    try:
        from repro import obs
        obs.counter(f"repair.{event}").inc()
    except Exception:
        pass


class BasketWriter:
    """Streaming writer with atomic commit.

    ``workers>0`` (or an explicit shared ``engine``) turns on the parallel
    I/O engine (repro.io.engine): baskets compress concurrently on a
    bounded pool while this thread commits payloads in offset order —
    output is byte-identical to the serial path.

    Crash safety: baskets stream to ``path + ".tmp"``; :meth:`close`
    writes the TOC, fsyncs, atomically renames onto ``path``, then fsyncs
    the directory — readers see the old generation, the new generation,
    or (for a torn external copy) a :class:`TruncatedContainerError`,
    never silently wrong bytes.  ``journal=True`` additionally appends a
    ``path + ".journal"`` sidecar (one JSON line per branch and basket,
    flushed as written); :func:`recover_container` uses it to salvage
    every basket preceding a tear.  The container bytes are identical
    either way — the journal is a sidecar, never part of the format.

    ``parity=k`` (k ≥ 2) additionally groups baskets, in write order,
    into k-wide XOR stripes and writes a ``path + ".parity"`` sidecar
    (repro.repair.stripe) committed *after* the container — any single
    damaged basket per stripe becomes reconstructible in place
    (``BasketFile(heal="auto")``).  Like the journal, parity never
    changes the container's own bytes.
    """

    def __init__(self, path: str, workers: int = 0, engine=None,
                 tuner=None, objective=None, journal: bool = False,
                 parity: int = 0):
        self.path = str(path)
        self._tmp = self.path + ".tmp"
        os.makedirs(os.path.dirname(os.path.abspath(self.path)), exist_ok=True)
        self._f = open(self._tmp, "wb")
        self._f.write(_MAGIC)
        self._branches: dict[str, dict] = {}
        self._closed = False
        self._failed = None          # first exception seen mid-write
        self._journal = None
        self._jpath = _journal_path(self.path)
        if journal:
            self._journal = open(self._jpath, "w")
            self._journal.write(json.dumps(
                {"magic": _JOURNAL_MAGIC,
                 "container": os.path.basename(self.path)}) + "\n")
            self._journal.flush()
        else:
            # a stale journal from an earlier journalled generation must
            # not describe this write's bytes
            try:
                os.remove(self._jpath)
            except OSError:
                pass
        self._parity = None
        if parity:
            from repro.repair.stripe import ParityWriter, parity_path
            self._parity = ParityWriter(parity_path(self.path), k=parity)
        else:
            # same staleness rule as the journal: a sidecar from an
            # earlier parity-protected generation must not describe this
            # write's bytes
            try:
                os.remove(self.path + ".parity")
            except OSError:
                pass
        self._engine = engine
        self._owns_engine = False
        if engine is None and workers:
            from repro.io.engine import CompressionEngine
            self._engine = CompressionEngine(workers)
            self._owns_engine = True
        # adaptive codec selection (repro.tune): branches written without
        # an explicit cfg are tuned per-branch; decisions persist in the
        # TOC so re-opens/appends reuse them without re-measurement
        if tuner is None and objective is not None:
            from repro.tune import Tuner
            tuner = Tuner(objective, engine=self._engine)
        self._tuner = tuner

    def write_branch(self, name: str, arr: np.ndarray,
                     cfg: Optional[CompressionConfig] = None,
                     target_basket_bytes: int = 1 << 20) -> dict:
        """Serialize an array column-wise into compressed baskets.

        With a tuner attached and no explicit ``cfg``, the config is the
        tuner's per-branch decision, measured here on stratified windows
        of the *whole* array (cached decisions are reused)."""
        arr = np.asarray(arr)
        if cfg is None and self._tuner is not None:
            cfg = self._tuner.config_for(name, arr)
        return self.write_branch_chunks(
            name, dtype=arr.dtype.str, shape=arr.shape,
            chunks=split_array(arr, target_basket_bytes), cfg=cfg)

    def write_branch_chunks(self, name: str, *, dtype, shape, chunks,
                            cfg: Optional[CompressionConfig] = None) -> dict:
        """Stream a branch from a ``(entry_start, entry_count, buffer)``
        chunk iterator without materializing the whole array — the
        checkpointer's device→host staging path.  Chunk boundaries are the
        caller's; to match :func:`write_branch` bytes exactly, produce
        the boundaries of :func:`repro.core.basket.basket_rows`."""
        if name in self._branches:
            raise ValueError(f"branch {name!r} already written")
        if cfg is None and self._tuner is not None:
            # streaming path: the tuner probes the first chunk (the only
            # data available without materializing the branch)
            it = iter(chunks)
            first = next(it, None)
            if first is not None:
                cfg = self._tuner.config_for(
                    name, first[2], dtype=np.dtype(dtype))
                chunks = itertools.chain([first], it)
        cfg = cfg or CompressionConfig()
        engine = self._engine
        if engine is None:
            from repro.io.engine import CompressionEngine
            engine = CompressionEngine(0)   # the serial path — no pools
        entry = {
            "dtype": np.dtype(dtype).str,
            "shape": list(shape),
            "config": {"algo": cfg.algo, "level": cfg.level, "precond": cfg.precond},
            "dictionary": base64.b64encode(cfg.dictionary).decode() if cfg.dictionary else None,
            "baskets": [],
        }
        self._journal_branch(name, entry)
        try:
            packed = engine.pack_stream(chunks, cfg)
            for _start, _count, payload, meta in packed:
                off = self._f.tell()
                self._f.write(payload)  # accepts memoryview payloads zero-copy
                if self._tuner is not None:
                    self._tuner.observe(name, meta)     # drift-detector feed
                if self._parity is not None:
                    self._parity.add(name, len(entry["baskets"]), payload)
                entry["baskets"].append({"offset": off, "meta": meta.to_json()})
                self._journal_basket(name, off, meta.to_json())
        except BaseException as e:
            self._failed = self._failed or e
            raise
        self._branches[name] = entry
        return entry

    def write_precompressed(self, name: str, *, dtype, shape, config,
                            dictionary, baskets) -> dict:
        """Append already-compressed ``(payload, meta_json)`` baskets as a
        branch — the BufferMerger/fast-merge path (no recompression)."""
        if name in self._branches:
            raise ValueError(f"branch {name!r} already written")
        entry = {"dtype": dtype, "shape": list(shape), "config": dict(config),
                 "dictionary": dictionary, "baskets": []}
        self._journal_branch(name, entry)
        try:
            for payload, meta_json in baskets:
                off = self._f.tell()
                self._f.write(payload)
                if self._parity is not None:
                    self._parity.add(name, len(entry["baskets"]), payload)
                entry["baskets"].append({"offset": off, "meta": dict(meta_json)})
                self._journal_basket(name, off, dict(meta_json))
        except BaseException as e:
            self._failed = self._failed or e
            raise
        self._branches[name] = entry
        return entry

    # -- write journal (recovery sidecar) --------------------------------

    def _journal_branch(self, name: str, entry: dict) -> None:
        if self._journal is None:
            return
        self._journal.write(json.dumps(
            {"branch": name, "dtype": entry["dtype"],
             "shape": entry["shape"], "config": entry["config"],
             "dictionary": entry["dictionary"]}) + "\n")
        self._journal.flush()

    def _journal_basket(self, name: str, offset: int, meta_json: dict) -> None:
        if self._journal is None:
            return
        self._journal.write(json.dumps(
            {"basket": name, "offset": offset, "meta": meta_json}) + "\n")
        self._journal.flush()

    def write_blob(self, name: str, raw: bytes, cfg: Optional[CompressionConfig] = None) -> None:
        """Opaque byte branch (metadata blobs, tokenizer state, ...)."""
        self.write_branch(name, np.frombuffer(raw, dtype=np.uint8), cfg)

    def close(self) -> None:
        if self._closed:
            return
        if self._failed is not None:
            # a basket write already failed: committing would publish a
            # container whose TOC describes bytes that were never written.
            # Abort instead and surface the original failure; subsequent
            # close() calls are no-ops (idempotent after failure).
            err = self._failed
            self.abort()
            raise RuntimeError(
                f"container write to {self.path!r} failed mid-stream; "
                f"aborted without committing: {err!r}") from err
        doc = {"branches": self._branches}
        if self._tuner is not None:
            # persist this file's tuning decisions in the header so appends
            # and re-opens (Tuner.from_file / load_decisions) reuse them
            # without re-measurement; decisions for branches not written
            # here are not this file's to record
            tuned = self._tuner.decisions_json(names=self._branches)
            if tuned:
                doc["tuning"] = tuned
        try:
            toc = json.dumps(doc).encode()
            self._f.write(toc)
            self._f.write(len(toc).to_bytes(8, "little"))
            self._f.write(_MAGIC)
            self._f.flush()
            size = self._f.tell()
            os.fsync(self._f.fileno())
            self._f.close()
            os.replace(self._tmp, self.path)  # atomic commit
        except BaseException:
            # commit failed (ENOSPC on the TOC, rename error, ...): never
            # leave the half-written tmp behind
            self.abort()
            raise
        # the rename is durable only once the directory entry is synced
        _fsync_dir(os.path.dirname(os.path.abspath(self.path)))
        if self._parity is not None:
            # sidecar commits strictly after the container: a crash here
            # leaves a valid container without parity, never the reverse
            from repro.repair.stripe import content_stamp
            self._parity.commit(self._branches, content_stamp(size, toc),
                                self.path)
            self._parity = None
        if self._journal is not None:
            # the journal now describes the committed bytes: keep it as
            # the recovery sidecar for torn copies of this container
            self._journal.flush()
            self._journal.close()
            self._journal = None
        self._closed = True
        if self._owns_engine:
            self._engine.close()

    def abort(self) -> None:
        if not self._closed:
            try:
                self._f.close()
            except OSError:
                pass
            if os.path.exists(self._tmp):
                os.remove(self._tmp)
            if self._journal is not None:
                try:
                    self._journal.close()
                    os.remove(self._jpath)
                except OSError:
                    pass
                self._journal = None
            if self._parity is not None:
                self._parity.abort()
                self._parity = None
            self._closed = True
            if self._owns_engine:
                self._engine.close()

    def __enter__(self):
        return self

    def __exit__(self, exc_type, *a):
        if exc_type is None:
            self.close()
        else:
            self.abort()


class BasketFile:
    """Reader with optional thread-pool parallel decompression.

    ``workers``/``prefetch`` delegate reads to the parallel I/O engine:
    ``workers`` sets the default decompression pool width, ``prefetch>0``
    routes ``read_branch``/``read_entries`` through a decompress-ahead
    :class:`repro.io.prefetch.PrefetchReader` (``prefetch`` = read-ahead
    depth in baskets) with an LRU decompressed-basket cache.

    ``heal="auto"`` turns a checksum-failing or torn basket read into a
    repair attempt instead of a quarantine dead end: the basket is
    re-read once (transient read rot clears on retry), else reconstructed
    from its XOR stripe peers + the ``.parity`` sidecar
    (``BasketWriter(parity=k)``), re-verified against the stored adler32,
    patched back **in place** (same inode — open readers stay valid), and
    served.  Healed/transient/failed outcomes are counted in
    ``self.heal_stats`` and the ``repair.*`` counters; an unhealable
    basket still raises :class:`CorruptBasketError`.
    """

    def __init__(self, path: str, verify: bool = True,
                 workers: int = 0, prefetch: int = 0,
                 heal: Optional[str] = None):
        if heal not in (None, "auto"):
            raise ValueError(f"heal must be None or 'auto', got {heal!r}")
        self.path = str(path)
        self.verify = verify
        self.heal = heal
        self.workers = workers
        self.prefetch = prefetch
        self._engine = None
        self._readers: dict = {}
        self._reader_lock = threading.Lock()
        self._closed = False
        with open(self.path, "rb") as f:
            # the generation of the inode whose TOC we are about to read:
            # every later pread checks against it, so a tmp-then-replace of
            # the path raises StaleFileError instead of slicing baskets out
            # of a file this TOC does not describe
            st = os.fstat(f.fileno())
            self.generation = (st.st_dev, st.st_ino)
            size = st.st_size
            head = f.read(8)
            if head != _MAGIC:
                if _MAGIC.startswith(head):
                    # a real container sheared off inside the header
                    raise TruncatedContainerError(
                        path, f"truncated container ({size} bytes)")
                raise ValueError(f"{path}: not a BasketFile (bad magic)")
            if size < 8 + 16:
                raise TruncatedContainerError(
                    path, f"truncated container ({size} bytes) — "
                          "incomplete write?")
            f.seek(-16, os.SEEK_END)
            toc_len = int.from_bytes(f.read(8), "little")
            if f.read(8) != _MAGIC:
                raise TruncatedContainerError(
                    path, "truncated (bad trailer) — incomplete write?")
            if not 2 <= toc_len <= size - 24:
                raise TruncatedContainerError(
                    path, f"TOC length {toc_len} inconsistent with "
                          f"file size {size}")
            f.seek(-16 - toc_len, os.SEEK_END)
            toc_bytes = f.read(toc_len)
            try:
                self._toc = json.loads(toc_bytes)
            except ValueError as e:
                raise TruncatedContainerError(
                    path, f"undecodable TOC — torn write? ({e})") from None
        # the content-derived stamp a parity sidecar must match before its
        # stripe map is trusted (repro.repair.stripe.content_stamp)
        self._content_stamp = {"size": int(size),
                               "toc_adler": int(adler32_hw(toc_bytes))}
        self._heal_lock = threading.Lock()
        self._parity_sc = None
        self.heal_stats = {"healed": 0, "transient": 0, "failed": 0}
        self.branches = self._toc["branches"]
        # per-branch autotuner decisions persisted at write time (may be
        # absent: files predating repro.tune, or written without a tuner)
        self.tuning = self._toc.get("tuning", {})

    def branch_names(self) -> list[str]:
        return list(self.branches)

    def tuning_decisions(self) -> dict[str, dict]:
        """Persisted per-branch tuner decisions (``{}`` when untuned) —
        feed to :meth:`repro.tune.Tuner.load` to append/re-open without
        re-measurement."""
        return dict(self.tuning)

    def _dictionary(self, entry: dict) -> Optional[bytes]:
        d = entry.get("dictionary")
        return base64.b64decode(d) if d else None

    def read_basket_payload(self, name: str, i: int) -> bytes:
        """Compressed on-disk payload of one basket (no decompression) —
        the fast-merge path."""
        entry = self.branches[name]
        b = entry["baskets"][i]
        return _pread(self.path, b["offset"], b["meta"]["comp_len"],
                      expect=self.generation)

    def read_basket_raw(self, name: str, i: int) -> bytes:
        entry = self.branches[name]
        b = entry["baskets"][i]
        meta = BasketMeta.from_json(b["meta"])
        try:
            payload = _pread(self.path, b["offset"], meta.comp_len,
                             expect=self.generation)
            return unpack_basket(payload, meta, self._dictionary(entry),
                                 verify=self.verify)
        except ChecksumError as e:
            if self.heal == "auto":
                return self._heal_basket(name, i, cause=e)
            raise self._quarantine(name, i, b, e) from e
        except _DECODE_ERRORS as e:
            # torn pread / undecodable payload — healable damage too, but
            # staleness (the file was replaced) must never be "healed"
            if self.heal == "auto":
                return self._heal_basket(name, i, cause=e)
            raise

    def read_basket_into(self, name: str, i: int, out) -> int:
        """Read + decode basket ``i`` directly into ``out`` (writable
        buffer ≥ ``orig_len`` bytes) — the zero-copy scatter step."""
        entry = self.branches[name]
        b = entry["baskets"][i]
        meta = BasketMeta.from_json(b["meta"])
        try:
            payload = _pread(self.path, b["offset"], meta.comp_len,
                             expect=self.generation)
            return unpack_basket_into(payload, meta, out,
                                      self._dictionary(entry),
                                      verify=self.verify)
        except ChecksumError as e:
            if self.heal == "auto":
                raw = self._heal_basket(name, i, cause=e)
                memoryview(out).cast("B")[:len(raw)] = raw
                return len(raw)
            raise self._quarantine(name, i, b, e) from e
        except _DECODE_ERRORS as e:
            if self.heal == "auto":
                raw = self._heal_basket(name, i, cause=e)
                memoryview(out).cast("B")[:len(raw)] = raw
                return len(raw)
            raise

    def _quarantine(self, name: str, i: int, b: dict,
                    cause) -> CorruptBasketError:
        """Turn a checksum failure into the structured error (counted in
        ``bfile.corrupt_baskets``) naming exactly what is damaged."""
        _count_corrupt()
        return CorruptBasketError(self.path, name, i, int(b["offset"]),
                                  cause=cause)

    # -- self-healing (repro.repair) -------------------------------------

    def _sidecar(self):
        """The parity sidecar, loaded once and stamp-checked against this
        container's committed content — a sidecar left over from an older
        generation must never donate stripes to these bytes."""
        if self._parity_sc is None:
            from repro.repair.stripe import ParityError, ParitySidecar, \
                parity_path
            sc = ParitySidecar.load(parity_path(self.path))
            if sc.stamp != self._content_stamp:
                raise ParityError(
                    f"{sc.path}: stamp {sc.stamp} does not match container "
                    f"content {self._content_stamp} — sidecar is for a "
                    "different generation")
            self._parity_sc = sc
        return self._parity_sc

    def _try_decode(self, name: str, i: int):
        """One pread + verified decode; ``None`` on any damage (a torn or
        rotted read), raising only for staleness."""
        from repro.io.fdcache import StaleFileError
        entry = self.branches[name]
        b = entry["baskets"][i]
        meta = BasketMeta.from_json(b["meta"])
        try:
            payload = _pread(self.path, b["offset"], meta.comp_len,
                             expect=self.generation)
            raw = unpack_basket(payload, meta, self._dictionary(entry),
                                verify=True)
            return payload, raw
        except StaleFileError:
            raise
        except _DECODE_ERRORS:
            return None

    def _read_peer(self, name: str, i: int) -> bytes:
        b = self.branches[name]["baskets"][i]
        return _pread(self.path, b["offset"], b["meta"]["comp_len"],
                      expect=self.generation)

    def _verify_peer(self, name: str, i: int, payload) -> bool:
        entry = self.branches[name]
        meta = BasketMeta.from_json(entry["baskets"][i]["meta"])
        try:
            unpack_basket(payload, meta, self._dictionary(entry),
                          verify=True)
            return True
        except _DECODE_ERRORS:
            return False

    def _heal_basket(self, name: str, i: int, cause=None) -> bytes:
        """Repair basket ``(name, i)`` and return its decoded raw bytes.

        Under the heal lock: (1) one verified re-read — transient read rot
        (a fault-hook garble, a racing heal by another thread) clears
        without touching parity; (2) reconstruct the on-disk payload from
        stripe peers + parity, decode-verify it against the stored
        adler32, and patch it back in place (same inode, so open readers
        and cache generations stay valid).  Reconstruction is retried a
        few times because the *reads* it depends on go through the same
        rot-prone pread path as the basket that failed.  Unhealable →
        ``repair.heal_failed`` + :class:`CorruptBasketError`."""
        from repro.repair.stripe import ParityError
        entry = self.branches[name]
        b = entry["baskets"][i]
        meta = BasketMeta.from_json(b["meta"])
        with self._heal_lock:
            got = self._try_decode(name, i)
            if got is not None:
                self.heal_stats["transient"] += 1
                _count_repair("transient")
                return got[1]
            candidate = raw = None
            last = None
            for _attempt in range(3):
                try:
                    sc = self._sidecar()
                    candidate = sc.reconstruct(
                        name, i, meta.comp_len,
                        self._read_peer, self._verify_peer)
                    raw = unpack_basket(candidate, meta,
                                        self._dictionary(entry), verify=True)
                    break
                except (ParityError,) + _DECODE_ERRORS as e:
                    last, candidate = e, None
            if candidate is None:
                self.heal_stats["failed"] += 1
                _count_repair("heal_failed")
                raise self._quarantine(name, i, b, cause or last)
            from repro.io import fdcache
            fdcache.patch(self.path, int(b["offset"]), candidate,
                          expect=self.generation)
            self.heal_stats["healed"] += 1
            _count_repair("healed")
            return raw

    def ensure_payload(self, name: str, i: int, payload=None) -> bytes:
        """Verified on-disk payload bytes for basket ``(name, i)``, healing
        in place when damaged — the serve-path hook (remote server, scrub).

        ``payload``, when given, is a candidate slice the caller already
        read; it is returned as-is if it decode-verifies.  Otherwise the
        basket is healed (:meth:`_heal_basket`) and re-read.  Raises
        :class:`CorruptBasketError` when unhealable."""
        entry = self.branches[name]
        b = entry["baskets"][i]
        meta = BasketMeta.from_json(b["meta"])
        if payload is not None and self._verify_peer(name, i, payload):
            return bytes(payload)
        self._heal_basket(name, i)
        last = None
        for _attempt in range(4):
            got = self._try_decode(name, i)
            if got is not None:
                return got[0]
        raise self._quarantine(name, i, b, last or "post-heal re-read "
                               "keeps failing")

    def _reader(self, name: str):
        """Cached PrefetchReader per branch (engine shared across them);
        locked — one BasketFile may serve readers on several threads."""
        with self._reader_lock:
            if name not in self._readers:
                from repro.io.engine import CompressionEngine
                from repro.io.prefetch import PrefetchReader
                if self._engine is None:
                    self._engine = CompressionEngine(self.workers or 2)
                self._readers[name] = PrefetchReader(
                    self, name, ahead=self.prefetch, engine=self._engine)
            return self._readers[name]

    @staticmethod
    def _byte_offsets(entry: dict) -> tuple[list[int], int]:
        return byte_offsets(b["meta"]["orig_len"] for b in entry["baskets"])

    def read_branch(self, name: str, workers: Optional[int] = None) -> np.ndarray:
        """Read + decompress a branch; ``workers>0`` = parallel decompression
        (the paper's simultaneous-read-and-decompress).

        Zero-copy plane: the destination array is allocated once and every
        basket decodes directly into its slice — no per-basket ``bytes``,
        no final concatenation."""
        if workers is None:
            workers = self.workers
        if self.prefetch:
            return self._reader(name).read_all()
        entry = self.branches[name]
        n = len(entry["baskets"])
        out = np.empty(tuple(entry["shape"]), dtype=np.dtype(entry["dtype"]))
        offs, total = self._byte_offsets(entry)
        if total != out.nbytes:
            # malformed TOC: fall back to the copying join (raises there)
            chunks = [self.read_basket_raw(name, i) for i in range(n)]
            return join_baskets(chunks, entry["dtype"], tuple(entry["shape"]))
        flat = out.reshape(-1).view(np.uint8)

        def scatter(i: int) -> None:
            ln = entry["baskets"][i]["meta"]["orig_len"]
            self.read_basket_into(name, i, flat[offs[i]:offs[i] + ln])

        if workers and n > 1:
            with ThreadPoolExecutor(max_workers=workers) as ex:
                list(ex.map(scatter, range(n)))
        else:
            for i in range(n):
                scatter(i)
        return out

    def read_entries(self, name: str, start: int, stop: int) -> np.ndarray:
        """Row-range read touching only the covering baskets (seekability).
        With ``prefetch>0`` the decompress-ahead reader also schedules the
        baskets *after* the range, hiding latency for forward scans."""
        if self.prefetch:
            return self._reader(name).read_entries(start, stop)
        entry = self.branches[name]
        shape = tuple(entry["shape"])
        dtype = np.dtype(entry["dtype"])
        cover, first_entry, total = [], None, 0
        for i, b in enumerate(entry["baskets"]):
            m = b["meta"]
            if m["entry_start"] + m["entry_count"] <= start or m["entry_start"] >= stop:
                continue
            if first_entry is None:
                first_entry = m["entry_start"]
            cover.append((i, total, m["orig_len"]))
            total += m["orig_len"]
        if not cover:
            return np.zeros((0,) + shape[1:], dtype=dtype)
        row_elems = int(np.prod(shape[1:], dtype=np.int64)) or 1
        rows = total // (dtype.itemsize * row_elems)
        arr = np.empty((rows,) + shape[1:], dtype=dtype)
        flat = arr.reshape(-1).view(np.uint8)
        for i, off, ln in cover:
            self.read_basket_into(name, i, flat[off:off + ln])
        return arr[start - first_entry: stop - first_entry].copy()

    def compressed_bytes(self, name: Optional[str] = None) -> int:
        names = [name] if name else self.branch_names()
        return sum(b["meta"]["comp_len"] for n in names for b in self.branches[n]["baskets"])

    def raw_bytes(self, name: Optional[str] = None) -> int:
        names = [name] if name else self.branch_names()
        return sum(b["meta"]["orig_len"] for n in names for b in self.branches[n]["baskets"])

    def compression_ratio(self, name: Optional[str] = None) -> float:
        c = self.compressed_bytes(name)
        return self.raw_bytes(name) / c if c else float("inf")

    def close(self) -> None:
        """Release prefetch readers, the engine pool, and this path's
        cached fd (so a long-lived server doesn't pin unlinked inodes
        until LRU eviction).  Idempotent: a second close is a no-op."""
        with self._reader_lock:
            if self._closed:
                return
            self._closed = True
            readers, self._readers = list(self._readers.values()), {}
            engine, self._engine = self._engine, None
        for r in readers:
            r.close()
        if engine is not None:
            engine.close()
        from repro.io import fdcache
        fdcache.invalidate(self.path)

    def __enter__(self):
        return self

    def __exit__(self, *a):
        self.close()

    @staticmethod
    def recover(path: str, out_path: Optional[str] = None) -> dict:
        """Salvage a torn container — see :func:`recover_container`."""
        return recover_container(path, out_path)


# ---------------------------------------------------------------------------
# crash recovery
# ---------------------------------------------------------------------------

def recover_container(path: str, out_path: Optional[str] = None) -> dict:
    """Salvage every intact basket preceding the tear of a torn container.

    ``path`` is a truncated/torn container (or a leftover ``*.tmp`` from a
    crashed writer).  Recovery needs the write journal sidecar
    (``BasketWriter(journal=True)``); without one the basket boundaries
    live only in the (lost) TOC and a structured
    :class:`TruncatedContainerError` says so.  Every candidate basket is
    decoded and checked against its stored adler32 before it is kept —
    a stale or mismatched journal can drop baskets but never resurrect
    wrong bytes.  A branch is cut at its first missing/corrupt basket so
    salvaged entry ranges stay contiguous from row 0.

    Writes a fresh, valid container to ``out_path`` (default
    ``path + ".recovered"``, committed atomically) and returns a report::

        {"out_path", "baskets_kept", "baskets_lost",
         "branches": {name: rows_kept}}
    """
    path = str(path)
    out_path = str(out_path) if out_path else path + ".recovered"
    jpath = _journal_path(path)
    try:
        size = os.path.getsize(path)
    except OSError as e:
        raise TruncatedContainerError(path, f"unreadable: {e}") from None
    with open(path, "rb") as f:
        head = f.read(8)
    if head != _MAGIC:
        if _MAGIC.startswith(head):
            raise TruncatedContainerError(
                path, "sheared inside the header — nothing to salvage")
        raise ValueError(f"{path}: not a BasketFile (bad magic)")
    # basket boundaries: the write journal when present, else the parity
    # sidecar's TOC mirror (BasketWriter(parity=k)) — either way, every
    # candidate basket is decode-verified below, so a stale boundary
    # source can drop baskets but never resurrect wrong bytes
    order: list[str] = []
    jbranches: dict[str, dict] = {}
    if os.path.exists(jpath):
        with open(jpath) as jf:
            first = jf.readline()
            try:
                if json.loads(first).get("magic") != _JOURNAL_MAGIC:
                    raise ValueError("bad journal magic")
            except ValueError as e:
                raise TruncatedContainerError(
                    path, f"unusable write journal {jpath}: {e}") from None
            for line in jf:
                line = line.strip()
                if not line:
                    continue
                try:
                    rec = json.loads(line)
                except ValueError:
                    break            # journal itself torn: keep what parsed
                if "branch" in rec:
                    order.append(rec["branch"])
                    jbranches[rec["branch"]] = {
                        "dtype": rec["dtype"], "shape": rec["shape"],
                        "config": rec["config"],
                        "dictionary": rec["dictionary"], "baskets": []}
                elif "basket" in rec and rec["basket"] in jbranches:
                    jbranches[rec["basket"]]["baskets"].append(
                        {"offset": int(rec["offset"]), "meta": rec["meta"]})
    else:
        from repro.repair.stripe import ParityError, ParitySidecar, \
            parity_path
        ppath = parity_path(path)
        try:
            sc = ParitySidecar.load(ppath)
        except ParityError:
            raise TruncatedContainerError(
                path, "cannot recover: no write journal sidecar "
                      f"({jpath} missing) and no parity sidecar "
                      f"({ppath}) — basket boundaries were lost with the "
                      "TOC; write with BasketWriter(journal=True) or "
                      "BasketWriter(parity=k) to make containers "
                      "salvageable") from None
        # no stamp check: a torn copy never matches the committed stamp —
        # that is exactly the case being recovered
        for bname, e in sc.branches.items():
            order.append(bname)
            jbranches[bname] = {
                "dtype": e["dtype"], "shape": list(e["shape"]),
                "config": dict(e["config"]),
                "dictionary": e.get("dictionary"),
                "baskets": [{"offset": int(b["offset"]),
                             "meta": dict(b["meta"])}
                            for b in e["baskets"]]}

    kept = lost = 0
    out_branches: dict[str, dict] = {}
    rows_kept: dict[str, int] = {}
    tmp = out_path + ".tmp"
    os.makedirs(os.path.dirname(os.path.abspath(out_path)), exist_ok=True)
    src = open(path, "rb")
    try:
        with open(tmp, "wb") as dst:
            dst.write(_MAGIC)
            for name in order:
                e = jbranches[name]
                dictionary = base64.b64decode(e["dictionary"]) \
                    if e["dictionary"] else None
                out_baskets = []
                rows = 0
                for b in e["baskets"]:
                    meta = BasketMeta.from_json(b["meta"])
                    end = b["offset"] + meta.comp_len
                    if end > size:
                        break       # the tear: nothing later is complete
                    src.seek(b["offset"])
                    payload = src.read(meta.comp_len)
                    try:
                        unpack_basket(payload, meta, dictionary, verify=True)
                    except (ChecksumError, ValueError, KeyError):
                        break       # cut the branch at the first bad basket
                    off = dst.tell()
                    dst.write(payload)
                    out_baskets.append({"offset": off, "meta": b["meta"]})
                    rows += int(meta.entry_count)
                    kept += 1
                lost += len(e["baskets"]) - len(out_baskets)
                if not out_baskets:
                    continue
                shape = list(e["shape"])
                if len(out_baskets) < len(e["baskets"]):
                    if not shape:
                        continue     # 0-d branch lost its only basket tail
                    # trim the leading dimension to the salvaged rows and
                    # require exact byte agreement — a partial basket can
                    # never smuggle a misaligned row count through
                    row_elems = 1
                    for d in shape[1:]:
                        row_elems *= int(d)
                    row_bytes = np.dtype(e["dtype"]).itemsize * row_elems
                    total = sum(b["meta"]["orig_len"] for b in out_baskets)
                    if row_bytes <= 0 or total % row_bytes:
                        continue
                    shape[0] = total // row_bytes
                    rows = shape[0]
                out_branches[name] = {
                    "dtype": e["dtype"], "shape": shape,
                    "config": e["config"], "dictionary": e["dictionary"],
                    "baskets": out_baskets}
                rows_kept[name] = rows
            toc = json.dumps({"branches": out_branches}).encode()
            dst.write(toc)
            dst.write(len(toc).to_bytes(8, "little"))
            dst.write(_MAGIC)
            dst.flush()
            os.fsync(dst.fileno())
        os.replace(tmp, out_path)
        _fsync_dir(os.path.dirname(os.path.abspath(out_path)))
    except BaseException:
        try:
            os.remove(tmp)
        except OSError:
            pass
        raise
    finally:
        src.close()
    return {"out_path": out_path, "baskets_kept": kept,
            "baskets_lost": lost, "branches": rows_kept}


# ---------------------------------------------------------------------------
# pytree-of-arrays convenience (used by the checkpointer)
# ---------------------------------------------------------------------------

def write_arrays(path: str, arrays: dict[str, np.ndarray],
                 cfg_for: Optional[callable] = None,
                 target_basket_bytes: int = 1 << 20,
                 workers: int = 0, tuner=None, objective=None,
                 parity: int = 0) -> None:
    """Write a flat dict of named arrays; ``cfg_for(name, arr)`` picks the
    per-branch CompressionConfig (the codec policy hook); ``workers>0``
    compresses baskets in parallel (identical bytes).  ``tuner=`` /
    ``objective=`` switch branches without an explicit config to
    measurement-driven selection (repro.tune).  ``parity=k`` writes the
    self-healing XOR sidecar (container bytes unchanged)."""
    with BasketWriter(path, workers=workers, tuner=tuner,
                      objective=objective, parity=parity) as w:
        for name, arr in arrays.items():
            cfg = cfg_for(name, np.asarray(arr)) if cfg_for else None
            w.write_branch(name, arr, cfg, target_basket_bytes)


def read_arrays(path: str, workers: int = 0, prefetch: int = 0) -> dict[str, np.ndarray]:
    with BasketFile(path, workers=workers, prefetch=prefetch) as f:
        return {name: f.read_branch(name, workers=workers)
                for name in f.branch_names()}
