"""Preconditioners: deterministic, invertible byte-stream transforms.

These reproduce the paper's §2.2 mechanism (Blosc-inspired Shuffle and
BitShuffle) plus Delta/Zigzag for offset arrays.  The paper's example:

    ROOT serializes a var-size branch as (payload, offset array).  The
    offset array is a near-arithmetic sequence of big-endian integers;
    byte-oriented LZ4 cannot compress it.  A stride-``itemsize`` byte
    transpose groups the (almost always equal) high bytes together,
    producing long runs LZ4 eats for breakfast.

All host-path transforms are pure numpy and exactly invertible:
``inverse(forward(x)) == x`` for every byte string whose length is a
multiple of ``itemsize`` (remainder bytes are passed through untouched,
matching Blosc semantics).

The device path (Pallas TPU kernels) lives in ``repro.kernels``; this module
is the reference implementation those kernels are tested against.
"""

from __future__ import annotations

import numpy as np

__all__ = [
    "shuffle",
    "unshuffle",
    "bitshuffle",
    "bitunshuffle",
    "delta_encode",
    "delta_decode",
    "zigzag_encode",
    "zigzag_decode",
    "PRECONDITIONERS",
    "apply_precond",
    "undo_precond",
    "undo_precond_into",
]


def _as_bytes(buf) -> np.ndarray:
    """View any buffer-protocol object as a flat uint8 array (zero-copy)."""
    a = np.frombuffer(buf, dtype=np.uint8) if not isinstance(buf, np.ndarray) else buf
    if a.dtype != np.uint8:
        a = a.view(np.uint8)
    return a.reshape(-1)


def _as_out(out) -> np.ndarray:
    """View a writable buffer-protocol object as a flat uint8 array."""
    if isinstance(out, np.ndarray):
        if not out.flags.c_contiguous:
            # reshape(-1) on a strided view would COPY and orphan the write
            raise ValueError("output array must be C-contiguous")
        a = out if out.dtype == np.uint8 else out.view(np.uint8)
        a = a.reshape(-1)
    else:
        mv = memoryview(out)
        if mv.readonly:
            raise ValueError("output buffer is read-only")
        a = np.frombuffer(mv, dtype=np.uint8)
    if not a.flags.writeable:
        raise ValueError("output buffer is read-only")
    return a


# ---------------------------------------------------------------------------
# Shuffle (byte transpose) — Blosc "shuffle"
# ---------------------------------------------------------------------------

def shuffle(buf, itemsize: int = 4) -> bytes:
    """Byte-transpose: [e0b0 e0b1 .. e1b0 e1b1 ..] -> [e0b0 e1b0 .. e0b1 e1b1 ..].

    The paper's example (stride 4, big-endian ints 1 and 2):
    ``00 00 00 01 00 00 00 02`` -> ``00 00 00 00 00 00 01 02``.
    """
    a = _as_bytes(buf)
    n = a.size - (a.size % itemsize)
    body, tail = a[:n], a[n:]
    out = body.reshape(-1, itemsize).T.reshape(-1)
    return out.tobytes() + tail.tobytes()


def unshuffle(buf, itemsize: int = 4) -> bytes:
    a = _as_bytes(buf)
    n = a.size - (a.size % itemsize)
    body, tail = a[:n], a[n:]
    out = body.reshape(itemsize, -1).T.reshape(-1)
    return out.tobytes() + tail.tobytes()


# ---------------------------------------------------------------------------
# BitShuffle (bit transpose) — Blosc "bitshuffle"
# ---------------------------------------------------------------------------

def bitshuffle(buf, itemsize: int = 4) -> bytes:
    """Bit-transpose within each block of ``itemsize`` elements' bits.

    Treats the input as N elements of ``itemsize`` bytes; emits, for each bit
    position 0..8*itemsize-1, the stream of that bit across all elements,
    packed 8 bits/byte.  Tail bytes (len % itemsize) pass through.
    """
    a = _as_bytes(buf)
    n = a.size - (a.size % itemsize)
    body, tail = a[:n], a[n:]
    if n == 0:
        return tail.tobytes()
    elems = body.reshape(-1, itemsize)                       # (N, itemsize)
    bits = np.unpackbits(elems, axis=1, bitorder="little")   # (N, 8*itemsize)
    bits_t = bits.T                                          # (8*itemsize, N)
    out = np.packbits(bits_t, axis=1, bitorder="little")     # (8*itemsize, ceil(N/8))
    return out.tobytes() + tail.tobytes()


def bitunshuffle(buf, itemsize: int = 4, nbytes: int | None = None) -> bytes:
    """Invert :func:`bitshuffle`.

    ``nbytes`` is the ORIGINAL body length (pre-shuffle, excluding tail); if
    None it is inferred assuming N was a multiple of 8 (exact when the
    original element count was a multiple of 8 — the basket layer always
    records nbytes explicitly, so the None path is only a convenience).
    """
    a = _as_bytes(buf)
    nbits = 8 * itemsize
    if nbytes is None:
        # total = nbits * ceil(N/8) + tail; assume tail < itemsize
        per_bit = a.size // nbits if a.size % nbits == 0 else None
        if per_bit is None:
            # find split honouring tail < itemsize
            for t in range(itemsize):
                if (a.size - t) % nbits == 0:
                    per_bit = (a.size - t) // nbits
                    break
            else:  # pragma: no cover - malformed input
                raise ValueError("cannot infer bitshuffle layout; pass nbytes")
            nbytes = per_bit * nbits - 0  # may overestimate N padding
        n_elems = per_bit * 8
        nbytes = n_elems * itemsize
    n_elems = nbytes // itemsize
    per_bit = (n_elems + 7) // 8
    body_len = nbits * per_bit
    body, tail = a[:body_len], a[body_len:]
    rows = body.reshape(nbits, per_bit)
    bits_t = np.unpackbits(rows, axis=1, bitorder="little")[:, :n_elems]  # (nbits, N)
    bits = bits_t.T                                                       # (N, nbits)
    elems = np.packbits(bits, axis=1, bitorder="little")                  # (N, itemsize)
    return elems.reshape(-1).tobytes() + tail.tobytes()


# ---------------------------------------------------------------------------
# Delta / Zigzag — for offset-array-like integer branches
# ---------------------------------------------------------------------------

def delta_encode(buf, itemsize: int = 4) -> bytes:
    """Element-wise delta over little-endian unsigned ints of ``itemsize``.

    Offset arrays (1,2,3,4,...) become (1,1,1,1,...): maximally compressible
    by any LZ77 codec.  Wraparound arithmetic makes this exactly invertible.
    """
    dtype = {1: np.uint8, 2: np.uint16, 4: np.uint32, 8: np.uint64}[itemsize]
    a = _as_bytes(buf)
    n = a.size - (a.size % itemsize)
    body, tail = a[:n], a[n:]
    v = body.view(dtype).copy()
    v[1:] = (v[1:] - v[:-1]).astype(dtype)
    return v.tobytes() + tail.tobytes()


def delta_decode(buf, itemsize: int = 4) -> bytes:
    dtype = {1: np.uint8, 2: np.uint16, 4: np.uint32, 8: np.uint64}[itemsize]
    a = _as_bytes(buf)
    n = a.size - (a.size % itemsize)
    body, tail = a[:n], a[n:]
    v = body.view(dtype)
    with np.errstate(over="ignore"):
        out = np.cumsum(v.astype(dtype), dtype=dtype)
    return out.tobytes() + tail.tobytes()


def zigzag_encode(buf, itemsize: int = 4) -> bytes:
    """Map signed -> unsigned so small-magnitude values have small encodings."""
    sdt = {1: np.int8, 2: np.int16, 4: np.int32, 8: np.int64}[itemsize]
    udt = {1: np.uint8, 2: np.uint16, 4: np.uint32, 8: np.uint64}[itemsize]
    a = _as_bytes(buf)
    n = a.size - (a.size % itemsize)
    body, tail = a[:n], a[n:]
    v = body.view(sdt).astype(np.int64)
    enc = ((v << 1) ^ (v >> 63)).astype(udt)
    return enc.tobytes() + tail.tobytes()


def zigzag_decode(buf, itemsize: int = 4) -> bytes:
    sdt = {1: np.int8, 2: np.int16, 4: np.int32, 8: np.int64}[itemsize]
    udt = {1: np.uint8, 2: np.uint16, 4: np.uint32, 8: np.uint64}[itemsize]
    a = _as_bytes(buf)
    n = a.size - (a.size % itemsize)
    body, tail = a[:n], a[n:]
    u = body.view(udt).astype(np.uint64)
    dec = ((u >> 1) ^ (-(u & 1)).astype(np.uint64)).astype(np.int64).astype(sdt)
    return dec.tobytes() + tail.tobytes()


# ---------------------------------------------------------------------------
# In-place inverses — the zero-copy decode path.  Each ``*_into`` writes the
# decoded bytes directly into a caller-provided buffer (the destination
# array slice in ``read_branch``), replacing the tobytes()+join copies of
# the byte-returning inverses above.  Semantics are identical:
# ``inv_into(fwd(x), itemsize, out) => out[:len(x)] == x``.
# ---------------------------------------------------------------------------

def _copy_into(buf, itemsize, out, nbytes=None) -> int:
    a = _as_bytes(buf)
    o = _as_out(out)
    o[:a.size] = a
    return a.size


def unshuffle_into(buf, itemsize: int, out, nbytes=None) -> int:
    a = _as_bytes(buf)
    o = _as_out(out)
    n = a.size - (a.size % itemsize)
    # direct scatter: the transpose assignment writes straight into ``out``
    o[:n].reshape(-1, itemsize)[...] = a[:n].reshape(itemsize, -1).T
    o[n:a.size] = a[n:]
    return a.size


def bitunshuffle_into(buf, itemsize: int, out, nbytes=None) -> int:
    dec = bitunshuffle(buf, itemsize, nbytes)   # packbits can't target out
    o = _as_out(out)
    o[:len(dec)] = np.frombuffer(dec, dtype=np.uint8)
    return len(dec)


def delta_decode_into(buf, itemsize: int, out, nbytes=None) -> int:
    dtype = {1: np.uint8, 2: np.uint16, 4: np.uint32, 8: np.uint64}[itemsize]
    a = _as_bytes(buf)
    o = _as_out(out)
    n = a.size - (a.size % itemsize)
    v = a[:n].view(dtype)
    with np.errstate(over="ignore"):
        dec = np.cumsum(v.astype(dtype), dtype=dtype)
    o[:n] = dec.view(np.uint8)
    o[n:a.size] = a[n:]
    return a.size


def zigzag_decode_into(buf, itemsize: int, out, nbytes=None) -> int:
    a = _as_bytes(buf)
    o = _as_out(out)
    n = a.size - (a.size % itemsize)
    dec = np.frombuffer(zigzag_decode(a[:n], itemsize), dtype=np.uint8)
    o[:n] = dec
    o[n:a.size] = a[n:]
    return a.size


# ---------------------------------------------------------------------------
# Registry — composable pipelines, named like "bitshuffle4", "delta4+shuffle4"
# ---------------------------------------------------------------------------

def _make_entry(fwd, inv, needs_len=False, inv_into=None):
    return {"fwd": fwd, "inv": inv, "needs_len": needs_len,
            "inv_into": inv_into or _copy_into}


PRECONDITIONERS = {
    "none": _make_entry(lambda b, i: bytes(_as_bytes(b)),
                        lambda b, i, n=None: bytes(_as_bytes(b)),
                        inv_into=_copy_into),
    "shuffle": _make_entry(shuffle, lambda b, i, n=None: unshuffle(b, i),
                           inv_into=unshuffle_into),
    "bitshuffle": _make_entry(bitshuffle, bitunshuffle, needs_len=True,
                              inv_into=bitunshuffle_into),
    "delta": _make_entry(delta_encode, lambda b, i, n=None: delta_decode(b, i),
                         inv_into=delta_decode_into),
    "zigzag": _make_entry(zigzag_encode, lambda b, i, n=None: zigzag_decode(b, i),
                          inv_into=zigzag_decode_into),
}


def _parse(spec: str):
    """'delta4+bitshuffle8' -> [('delta',4), ('bitshuffle',8)]."""
    stages = []
    for part in spec.split("+"):
        part = part.strip()
        if not part or part == "none":
            continue
        name = part.rstrip("0123456789")
        size = part[len(name):]
        stages.append((name, int(size) if size else 4))
    return stages


def apply_precond(spec: str, buf) -> bytes:
    """Run the forward pipeline.  Accepts any buffer-protocol object and
    defers the first copy to the first stage (each stage reads its input
    through a zero-copy uint8 view); with no stages the input is only
    materialized if it isn't ``bytes`` already."""
    stages = _parse(spec)
    if not stages:
        return buf if isinstance(buf, bytes) else bytes(_as_bytes(buf))
    out = buf
    for name, itemsize in stages:
        out = PRECONDITIONERS[name]["fwd"](out, itemsize)
    return out


def _needs_n(ent: dict, itemsize: int, orig_len: int | None) -> int | None:
    if not ent["needs_len"] or orig_len is None:
        return None
    return orig_len - (orig_len % itemsize)


def undo_precond(spec: str, buf, orig_len: int | None = None) -> bytes:
    stages = _parse(spec)
    if not stages:
        return buf if isinstance(buf, bytes) else bytes(_as_bytes(buf))
    out = buf
    for name, itemsize in reversed(stages):
        ent = PRECONDITIONERS[name]
        if ent["needs_len"]:
            out = ent["inv"](out, itemsize, _needs_n(ent, itemsize, orig_len))
        else:
            out = ent["inv"](out, itemsize)
    return out


def undo_precond_into(spec: str, buf, out, orig_len: int | None = None) -> int:
    """Invert the pipeline, writing the final stage directly into ``out``
    (a writable buffer-protocol object).  Intermediate stages still
    materialize (they are different lengths for bitshuffle), but the last
    inverse — the one that used to feed ``b"".join`` — lands in place.
    Returns the number of bytes written."""
    stages = list(reversed(_parse(spec)))
    if not stages:
        return _copy_into(buf, 1, out)
    cur = buf
    for name, itemsize in stages[:-1]:
        ent = PRECONDITIONERS[name]
        if ent["needs_len"]:
            cur = ent["inv"](cur, itemsize, _needs_n(ent, itemsize, orig_len))
        else:
            cur = ent["inv"](cur, itemsize)
    name, itemsize = stages[-1]
    ent = PRECONDITIONERS[name]
    return ent["inv_into"](cur, itemsize, out, _needs_n(ent, itemsize, orig_len))
