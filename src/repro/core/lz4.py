"""From-scratch LZ4 *block format* codec (paper §2.2) — vectorized cores.

The real ``lz4`` bindings are not available offline, so this implements the
LZ4 block wire format (https://github.com/lz4/lz4 — lz4_Block_format.md)
independently:

  sequence := token | [litlen ext 255*] | literals | offset(2B LE)
              | [matchlen ext 255*]
  token    := (literal_length:4 | match_length-4 :4)
  rules    := last sequence is literals-only; matches >= 4 bytes;
              offset in [1, 65535]; last 5 bytes are always literals;
              last match must end >= 12 bytes before the block end.

Two compressors, mirroring the reference library:

* ``level <= 3`` — **fast/greedy**: single-probe hash table (the reference
  LZ4 fast path), with candidate positions probed in vectorized chunks —
  ``table[hashes[i:i+K]]`` is compared against the precomputed 4-byte
  words of a whole chunk at once, the first accepted match resolved, and
  the scan jumps past it (the paper's SIMD quadruplet-hashing mechanism
  applied to the probe loop itself, not just the hash precompute).
* ``level >= 4`` — **HC-ish**: chained hash search; chain depth grows with
  level ("LZ4-HC typically results in ~20% better ratio", paper §2.2).

``decompress_block`` is two-pass: pass 1 parses every sequence header into
numpy ``(litstart, litlen, offset, mlen)`` arrays in one cheap scan (token
positions only; extension bytes are rare and patched sparsely), pass 2
derives all output positions from one cumulative sum, scatters every
literal run with a single vectorized gather, and replays matches as plain
slice memcpys.  The pre-vectorization serial decoder is kept as
``_decompress_block_legacy`` — it is the baseline ``benchmarks/
fig_entropy.py`` and the CI perf-smoke compare against.

The numpy cores lift throughput well above the old per-sequence Python
loops (see ``benchmarks/fig_entropy.py`` for current numbers), but this is
still interpreter-orchestrated numpy, not native code: absolute MB/s
remains far below C lz4, so benchmarks keep reporting the handicap
explicitly (EXPERIMENTS.md §Fidelity) and use C-backed zstd negative
levels as the native-speed LZ4-class proxy.
"""

from __future__ import annotations

import numpy as np

from . import tokexec as _tok

__all__ = ["compress_block", "decompress_block"]

_MIN_MATCH = 4
_MFLIMIT = 12      # last match must end this many bytes before block end
_LAST_LITERALS = 5
_PROBE_CHUNK = 64  # greedy fast path: candidate positions probed per batch


def _words4(data: np.ndarray) -> np.ndarray:
    """Little-endian 4-byte window ("quadruplet") at every position."""
    n = data.size
    if n < 4:
        return np.zeros(0, dtype=np.uint32)
    return (
        data[: n - 3].astype(np.uint32)
        | (data[1: n - 2].astype(np.uint32) << 8)
        | (data[2: n - 1].astype(np.uint32) << 16)
        | (data[3:].astype(np.uint32) << 24)
    )


def _hash_words(words: np.ndarray, log2_size: int) -> np.ndarray:
    """Vectorized multiplicative hash of precomputed 4-byte windows."""
    return ((words * np.uint32(2654435761))
            >> np.uint32(32 - log2_size)).astype(np.uint32)


def _match_len(a: np.ndarray, i: int, j: int, limit: int) -> int:
    """Length of common prefix of a[i:limit] and a[j:...] (vectorized probe)."""
    n = limit - i
    if n <= 0:
        return 0
    step = 64
    total = 0
    while total < n:
        k = min(step, n - total)
        x = a[i + total: i + total + k]
        y = a[j + total: j + total + k]
        neq = np.nonzero(x != y)[0]
        if neq.size:
            return total + int(neq[0])
        total += k
        step = min(step * 4, 1 << 16)
    return n


def compress_block(data: bytes, level: int = 1, dict_prefix: bytes = b"") -> bytes:
    """Compress ``data`` into an LZ4 block. Never fails; worst case expands.

    ``dict_prefix`` primes the match window (real-LZ4 dictionary mode): the
    prefix seeds the hash table and is matchable, but is never emitted —
    the decoder must be given the same prefix.
    """
    if not isinstance(data, (bytes, bytearray, memoryview)):
        data = memoryview(data).cast("B")   # buffer-protocol input, zero-copy
    prefix = dict_prefix[-65535:] if dict_prefix else b""
    plen = len(prefix)
    if plen:
        buf = prefix + bytes(data)
        src = np.frombuffer(buf, dtype=np.uint8)
        data = buf  # emit() slices literals out of the combined buffer
    else:
        src = np.frombuffer(data, dtype=np.uint8)
    n = src.size
    out = bytearray()
    if n == plen:
        return b"\x00"

    def emit(lit_start: int, lit_end: int, mlen: int, dist: int):
        litlen = lit_end - lit_start
        token_lit = 15 if litlen >= 15 else litlen
        token_match = 0 if mlen == 0 else (15 if mlen - _MIN_MATCH >= 15 else mlen - _MIN_MATCH)
        out.append((token_lit << 4) | token_match)
        if litlen >= 15:
            rem = litlen - 15
            while rem >= 255:
                out.append(255)
                rem -= 255
            out.append(rem)
        out.extend(data[lit_start:lit_end])
        if mlen:
            out.append(dist & 0xFF)
            out.append((dist >> 8) & 0xFF)
            if mlen - _MIN_MATCH >= 15:
                rem = mlen - _MIN_MATCH - 15
                while rem >= 255:
                    out.append(255)
                    rem -= 255
                out.append(rem)

    if n - plen < _MFLIMIT + 1:
        emit(plen, n, 0, 0)
        return bytes(out)

    log2_size = 14 if level <= 3 else 16
    words = _words4(src)
    hashes = _hash_words(words, log2_size)
    match_limit = n - _LAST_LITERALS
    scan_limit = n - _MFLIMIT

    if level <= 3:
        # ---- greedy fast path: single-slot hash table, batched probing.
        # Probe _PROBE_CHUNK candidate positions per step: one gather pulls
        # all their table slots, one compare accepts/rejects every quadruplet
        # at once, and only an accepted match drops back to scalar code.
        # The table is only refreshed per chunk, which would go blind to
        # matches closer than the chunk (runs, byte-plane periodicity), so a
        # one-pass periodic-candidate table covers distances 1..4.
        near = np.zeros(hashes.size, dtype=np.uint8)
        for delta in (4, 3, 2, 1):  # smallest period wins (longest extension)
            eq = words[delta:] == words[:-delta]
            near[delta:][eq] = delta
        table = np.full(1 << log2_size, -1, dtype=np.int64)
        seed = min(plen, hashes.size)
        if seed:  # dictionary prefix; duplicate hashes keep the last (newest)
            table[hashes[:seed]] = np.arange(seed)
        anchor = plen
        i = plen
        while i < scan_limit:
            end = min(i + _PROBE_CHUNK, scan_limit)
            pos = np.arange(i, end, dtype=np.int64)
            hs = hashes[i:end]
            cands = table[hs]
            nd = near[i:end]
            # cands == -1 gathers words[-1]: in-bounds garbage, masked below
            ok = (nd > 0) | ((cands >= 0) & (pos - cands <= 65535)
                             & (words[cands] == words[pos]))
            hits = np.flatnonzero(ok)
            if hits.size == 0:
                table[hs] = pos
                i = end
                continue
            j = int(hits[0])
            table[hs[:j + 1]] = pos[:j + 1]
            ii = i + j
            cand = ii - int(nd[j]) if nd[j] else int(cands[j])
            # quadruplet equality guarantees >= _MIN_MATCH here: scan stops
            # _MFLIMIT before the end, so ii+4 is always under match_limit
            mlen = _match_len(src, ii, cand, match_limit)
            emit(anchor, ii, mlen, ii - cand)
            i = ii + mlen
            anchor = i
    else:
        # ---- HC path: chained hash search, depth scales with level
        depth = {4: 4, 5: 8, 6: 16, 7: 32, 8: 64, 9: 128}.get(min(level, 9), 16)
        head = np.full(1 << log2_size, -1, dtype=np.int64)
        prev = np.full(n, -1, dtype=np.int64)
        for j in range(0, min(plen, hashes.size)):   # seed with dictionary
            hj = hashes[j]
            prev[j] = head[hj]
            head[hj] = j
        anchor = plen
        i = plen
        while i < scan_limit:
            h = hashes[i]
            cand = head[h]
            best_len, best_dist = 0, 0
            tries = depth
            while cand >= 0 and tries > 0 and i - cand <= 65535:
                # quick reject: a longer match must at least extend past best_len
                probe = i + best_len
                if probe < match_limit and cand + best_len < n and src[cand + best_len] == src[probe]:
                    mlen = _match_len(src, i, cand, match_limit)
                    if mlen > best_len:
                        best_len, best_dist = mlen, i - cand
                cand = prev[cand]
                tries -= 1
            prev[i] = head[h]
            head[h] = i
            if best_len >= _MIN_MATCH:
                emit(anchor, i, best_len, best_dist)
                # insert skipped positions into the chain (sparsely, for speed)
                for j in range(i + 1, min(i + best_len, scan_limit), 4):
                    hj = hashes[j]
                    prev[j] = head[hj]
                    head[hj] = j
                i += best_len
                anchor = i
            else:
                i += 1

    emit(anchor, n, 0, 0)  # trailing literals
    return bytes(out)


def decompress_block(comp: bytes, orig_len: int, dict_prefix: bytes = b"") -> bytes:
    """Decompress an LZ4 block of known decompressed size (two-pass,
    vectorized — see ``repro.core.tokexec``).

    ``dict_prefix`` must be the same window-priming dictionary used at
    compression time (matches may reference into it)."""
    prefix = dict_prefix[-65535:] if dict_prefix else b""
    return _tok.decode_token_stream(comp, prefix, orig_len, base=0,
                                    offset_bytes=2, name="LZ4 block")


def _decompress_block_legacy(comp: bytes, orig_len: int,
                             dict_prefix: bytes = b"") -> bytes:
    """The pre-vectorization single-pass serial decoder, kept verbatim as
    the perf baseline for ``benchmarks/fig_entropy.py`` and as a cross-check
    oracle in tests."""
    prefix = dict_prefix[-65535:] if dict_prefix else b""
    plen = len(prefix)
    src = comp
    dst = bytearray(plen + orig_len)
    dst[:plen] = prefix
    i = 0
    o = plen
    n = len(src)
    while i < n:
        token = src[i]
        i += 1
        litlen = token >> 4
        if litlen == 15:
            while True:
                b = src[i]
                i += 1
                litlen += b
                if b != 255:
                    break
        if litlen:
            dst[o:o + litlen] = src[i:i + litlen]
            i += litlen
            o += litlen
        if i >= n:
            break  # last sequence: literals only
        dist = src[i] | (src[i + 1] << 8)
        i += 2
        mlen = (token & 0xF) + _MIN_MATCH
        if (token & 0xF) == 15:
            while True:
                b = src[i]
                i += 1
                mlen += b
                if b != 255:
                    break
        ref = o - dist
        if dist >= mlen:  # non-overlapping: one slice copy
            dst[o:o + mlen] = dst[ref:ref + mlen]
            o += mlen
        else:             # overlapping match: replicate pattern
            while mlen > 0:
                chunk = min(mlen, o - ref)
                dst[o:o + chunk] = dst[ref:ref + chunk]
                o += chunk
                mlen -= chunk
    if o - plen != orig_len:
        raise ValueError(f"LZ4 block decoded {o - plen} bytes, expected {orig_len}")
    return bytes(dst[plen:])
