"""From-scratch LZ4 *block format* codec (paper §2.2).

The real ``lz4`` bindings are not available offline, so this implements the
LZ4 block wire format (https://github.com/lz4/lz4 — lz4_Block_format.md)
independently:

  sequence := token | [litlen ext 255*] | literals | offset(2B LE)
              | [matchlen ext 255*]
  token    := (literal_length:4 | match_length-4 :4)
  rules    := last sequence is literals-only; matches >= 4 bytes;
              offset in [1, 65535]; last 5 bytes are always literals;
              last match must end >= 12 bytes before the block end.

Two compressors, mirroring the reference library:

* ``level <= 3`` — **fast/greedy**: single-probe hash table (the reference
  LZ4 fast path) with an acceleration skip on incompressible stretches.
* ``level >= 4`` — **HC-ish**: chained hash search; chain depth grows with
  level ("LZ4-HC typically results in ~20% better ratio", paper §2.2).

The matcher hashes 4-byte windows ("quadruplets" — the same granularity the
paper highlights for CF-ZLIB's fast levels) with hashes precomputed for the
whole buffer in one vectorized numpy pass — the SIMD-hashing analogue.

Pure-Python sequence loops bound absolute MB/s; benchmarks report this
handicap explicitly (EXPERIMENTS.md §Fidelity) and use C-backed zstd
negative levels as the native-speed LZ4-class proxy.
"""

from __future__ import annotations

import numpy as np

__all__ = ["compress_block", "decompress_block"]

_MIN_MATCH = 4
_MFLIMIT = 12      # last match must end this many bytes before block end
_LAST_LITERALS = 5


def _hash_all(data: np.ndarray, log2_size: int) -> np.ndarray:
    """Vectorized 4-byte-window multiplicative hash for every position."""
    n = data.size
    if n < 4:
        return np.zeros(0, dtype=np.uint32)
    w = (
        data[: n - 3].astype(np.uint32)
        | (data[1: n - 2].astype(np.uint32) << 8)
        | (data[2: n - 1].astype(np.uint32) << 16)
        | (data[3:].astype(np.uint32) << 24)
    )
    return ((w * np.uint32(2654435761)) >> np.uint32(32 - log2_size)).astype(np.uint32)


def _match_len(a: np.ndarray, i: int, j: int, limit: int) -> int:
    """Length of common prefix of a[i:limit] and a[j:...] (vectorized probe)."""
    n = limit - i
    if n <= 0:
        return 0
    step = 64
    total = 0
    while total < n:
        k = min(step, n - total)
        x = a[i + total: i + total + k]
        y = a[j + total: j + total + k]
        neq = np.nonzero(x != y)[0]
        if neq.size:
            return total + int(neq[0])
        total += k
        step = min(step * 4, 1 << 16)
    return n


def compress_block(data: bytes, level: int = 1, dict_prefix: bytes = b"") -> bytes:
    """Compress ``data`` into an LZ4 block. Never fails; worst case expands.

    ``dict_prefix`` primes the match window (real-LZ4 dictionary mode): the
    prefix seeds the hash table and is matchable, but is never emitted —
    the decoder must be given the same prefix.
    """
    prefix = dict_prefix[-65535:] if dict_prefix else b""
    plen = len(prefix)
    if plen:
        buf = prefix + data
        src = np.frombuffer(buf, dtype=np.uint8)
        data = buf  # emit() slices literals out of the combined buffer
    else:
        src = np.frombuffer(data, dtype=np.uint8)
    n = src.size
    out = bytearray()
    if n == plen:
        return b"\x00"

    def emit(lit_start: int, lit_end: int, mlen: int, dist: int):
        litlen = lit_end - lit_start
        token_lit = 15 if litlen >= 15 else litlen
        token_match = 0 if mlen == 0 else (15 if mlen - _MIN_MATCH >= 15 else mlen - _MIN_MATCH)
        out.append((token_lit << 4) | token_match)
        if litlen >= 15:
            rem = litlen - 15
            while rem >= 255:
                out.append(255)
                rem -= 255
            out.append(rem)
        out.extend(data[lit_start:lit_end])
        if mlen:
            out.append(dist & 0xFF)
            out.append((dist >> 8) & 0xFF)
            if mlen - _MIN_MATCH >= 15:
                rem = mlen - _MIN_MATCH - 15
                while rem >= 255:
                    out.append(255)
                    rem -= 255
                out.append(rem)

    if n - plen < _MFLIMIT + 1:
        emit(plen, n, 0, 0)
        return bytes(out)

    log2_size = 14 if level <= 3 else 16
    hashes = _hash_all(src, log2_size)
    match_limit = n - _LAST_LITERALS
    scan_limit = n - _MFLIMIT

    if level <= 3:
        # ---- greedy fast path: single-slot hash table + acceleration skip
        table = np.full(1 << log2_size, -1, dtype=np.int64)
        for j in range(0, min(plen, hashes.size)):   # seed with dictionary
            table[hashes[j]] = j
        anchor = plen
        i = plen
        searches = 0
        accel_shift = 6  # reference LZ4: skip grows after misses
        while i < scan_limit:
            h = hashes[i]
            cand = table[h]
            table[h] = i
            if cand >= 0 and i - cand <= 65535 and src[cand] == src[i] and \
                    np.array_equal(src[cand:cand + 4], src[i:i + 4]):
                mlen = _match_len(src, i, cand, match_limit)
                if mlen >= _MIN_MATCH:
                    emit(anchor, i, mlen, i - cand)
                    i += mlen
                    anchor = i
                    searches = 0
                    continue
            searches += 1
            i += 1 + (searches >> accel_shift)
    else:
        # ---- HC path: chained hash search, depth scales with level
        depth = {4: 4, 5: 8, 6: 16, 7: 32, 8: 64, 9: 128}.get(min(level, 9), 16)
        head = np.full(1 << log2_size, -1, dtype=np.int64)
        prev = np.full(n, -1, dtype=np.int64)
        for j in range(0, min(plen, hashes.size)):   # seed with dictionary
            hj = hashes[j]
            prev[j] = head[hj]
            head[hj] = j
        anchor = plen
        i = plen
        while i < scan_limit:
            h = hashes[i]
            cand = head[h]
            best_len, best_dist = 0, 0
            tries = depth
            while cand >= 0 and tries > 0 and i - cand <= 65535:
                # quick reject: a longer match must at least extend past best_len
                probe = i + best_len
                if probe < match_limit and cand + best_len < n and src[cand + best_len] == src[probe]:
                    mlen = _match_len(src, i, cand, match_limit)
                    if mlen > best_len:
                        best_len, best_dist = mlen, i - cand
                cand = prev[cand]
                tries -= 1
            prev[i] = head[h]
            head[h] = i
            if best_len >= _MIN_MATCH:
                emit(anchor, i, best_len, best_dist)
                # insert skipped positions into the chain (sparsely, for speed)
                for j in range(i + 1, min(i + best_len, scan_limit), 4):
                    hj = hashes[j]
                    prev[j] = head[hj]
                    head[hj] = j
                i += best_len
                anchor = i
            else:
                i += 1

    emit(anchor, n, 0, 0)  # trailing literals
    return bytes(out)


def decompress_block(comp: bytes, orig_len: int, dict_prefix: bytes = b"") -> bytes:
    """Decompress an LZ4 block of known decompressed size.

    ``dict_prefix`` must be the same window-priming dictionary used at
    compression time (matches may reference into it)."""
    prefix = dict_prefix[-65535:] if dict_prefix else b""
    plen = len(prefix)
    src = comp
    dst = bytearray(plen + orig_len)
    dst[:plen] = prefix
    i = 0
    o = plen
    n = len(src)
    while i < n:
        token = src[i]
        i += 1
        litlen = token >> 4
        if litlen == 15:
            while True:
                b = src[i]
                i += 1
                litlen += b
                if b != 255:
                    break
        if litlen:
            dst[o:o + litlen] = src[i:i + litlen]
            i += litlen
            o += litlen
        if i >= n:
            break  # last sequence: literals only
        dist = src[i] | (src[i + 1] << 8)
        i += 2
        mlen = (token & 0xF) + _MIN_MATCH
        if (token & 0xF) == 15:
            while True:
                b = src[i]
                i += 1
                mlen += b
                if b != 255:
                    break
        ref = o - dist
        if dist >= mlen:  # non-overlapping: one slice copy
            dst[o:o + mlen] = dst[ref:ref + mlen]
            o += mlen
        else:             # overlapping match: replicate pattern
            while mlen > 0:
                chunk = min(mlen, o - ref)
                dst[o:o + chunk] = dst[ref:ref + chunk]
                o += chunk
                mlen -= chunk
    if o - plen != orig_len:
        raise ValueError(f"LZ4 block decoded {o - plen} bytes, expected {orig_len}")
    return bytes(dst[plen:])
