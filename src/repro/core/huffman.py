"""Canonical Huffman coder over byte symbols.

The entropy stage for ``repro_deflate`` (and its large-window "repro-zstd"
variant).  ZLIB's second pass is Huffman coding (paper §2); this module is a
self-contained, numpy-vectorized encoder with a table-driven decoder so the
paper's "entropy stage" mechanism exists in our from-scratch codec rather
than being inherited opaquely from libz.

Wire format (little-endian bit order within bytes)::

    [2B n_symbols_present][for each present symbol: 1B symbol, then packed
     4-bit code lengths][4B n_encoded_symbols][packed bitstream]

Code lengths are capped at 15 bits (deflate's own cap) via the standard
length-limiting fix-up.
"""

from __future__ import annotations

import heapq

import numpy as np

__all__ = ["encode", "decode", "code_lengths", "canonical_codes"]

_MAX_BITS = 15


def code_lengths(freqs: np.ndarray) -> np.ndarray:
    """Huffman code lengths (capped at _MAX_BITS) for a 256-entry freq table."""
    sym = np.nonzero(freqs)[0]
    n = sym.size
    lengths = np.zeros(256, dtype=np.uint8)
    if n == 0:
        return lengths
    if n == 1:
        lengths[sym[0]] = 1
        return lengths
    # heap of (freq, tiebreak, node); leaves are ints, internals are tuples
    heap = [(int(freqs[s]), int(s), int(s)) for s in sym]
    heapq.heapify(heap)
    cnt = 256
    while len(heap) > 1:
        f1, _, n1 = heapq.heappop(heap)
        f2, _, n2 = heapq.heappop(heap)
        heapq.heappush(heap, (f1 + f2, cnt, (n1, n2)))
        cnt += 1
    # walk tree for depths
    stack = [(heap[0][2], 0)]
    while stack:
        node, d = stack.pop()
        if isinstance(node, tuple):
            stack.append((node[0], d + 1))
            stack.append((node[1], d + 1))
        else:
            lengths[node] = max(d, 1)
    # length-limit to _MAX_BITS (Kraft fix-up: demote overlong, then re-pay)
    if lengths.max() > _MAX_BITS:
        lengths = np.minimum(lengths, _MAX_BITS)
        # Kraft sum K = sum(2^-len) must be <= 1 in units of 2^-MAX_BITS;
        # demote (lengthen) the rarest symbols until it holds.
        unit = 1 << _MAX_BITS
        k = int(np.sum(unit >> lengths[lengths > 0].astype(np.int64)))
        if k > unit:
            # demote symbols with the smallest freq first
            order = np.argsort(freqs + (lengths == 0) * (1 << 62))
            i = 0
            while k > unit and i < order.size:
                s = order[i]
                while lengths[s] < _MAX_BITS and k > unit:
                    k -= unit >> int(lengths[s])
                    lengths[s] += 1
                    k += unit >> int(lengths[s])
                i += 1
    return lengths


def canonical_codes(lengths: np.ndarray) -> np.ndarray:
    """Canonical code values (uint16) for given code lengths."""
    codes = np.zeros(256, dtype=np.uint16)
    code = 0
    for bits in range(1, _MAX_BITS + 1):
        for s in np.nonzero(lengths == bits)[0]:
            codes[s] = code
            code += 1
        code <<= 1
    return codes


def _pack_bits(symbols: np.ndarray, codes: np.ndarray, lengths: np.ndarray) -> bytes:
    """Vectorized bit-packing of per-symbol canonical codes (MSB-first)."""
    lens = lengths[symbols].astype(np.int64)
    total = int(lens.sum())
    if total == 0:
        return b""
    starts = np.concatenate([[0], np.cumsum(lens)[:-1]])
    bits = np.zeros(total, dtype=np.uint8)
    cvals = codes[symbols].astype(np.uint32)
    maxlen = int(lens.max())
    for p in range(maxlen):              # <=15 iterations, each fully vectorized
        sel = lens > p
        if not sel.any():
            break
        shift = (lens[sel] - 1 - p).astype(np.uint32)
        bits[starts[sel] + p] = (cvals[sel] >> shift) & 1
    return np.packbits(bits).tobytes()


def encode(data: bytes) -> bytes:
    """Huffman-encode a byte string (self-describing header + bitstream)."""
    arr = np.frombuffer(data, dtype=np.uint8)
    out = bytearray()
    if arr.size == 0:
        return bytes([0, 0]) + (0).to_bytes(4, "little")
    freqs = np.bincount(arr, minlength=256)
    lengths = code_lengths(freqs)
    codes = canonical_codes(lengths)
    present = np.nonzero(lengths)[0]
    out += int(present.size).to_bytes(2, "little")
    out += present.astype(np.uint8).tobytes()
    # 4-bit lengths, two per byte
    ls = lengths[present]
    if ls.size % 2:
        ls = np.concatenate([ls, [0]])
    out += ((ls[0::2].astype(np.uint8) << 4) | ls[1::2].astype(np.uint8)).tobytes()
    out += int(arr.size).to_bytes(4, "little")
    out += _pack_bits(arr, codes, lengths)
    return bytes(out)


def decode(blob: bytes) -> bytes:
    """Invert :func:`encode` via a 2^maxbits lookup table."""
    n_present = int.from_bytes(blob[:2], "little")
    pos = 2
    if n_present == 0:
        return b""
    present = np.frombuffer(blob[pos:pos + n_present], dtype=np.uint8)
    pos += n_present
    n_len_bytes = (n_present + 1) // 2
    packed = np.frombuffer(blob[pos:pos + n_len_bytes], dtype=np.uint8)
    pos += n_len_bytes
    ls = np.zeros(n_len_bytes * 2, dtype=np.uint8)
    ls[0::2] = packed >> 4
    ls[1::2] = packed & 0xF
    lengths = np.zeros(256, dtype=np.uint8)
    lengths[present] = ls[:n_present]
    n_syms = int.from_bytes(blob[pos:pos + 4], "little")
    pos += 4
    codes = canonical_codes(lengths)
    maxbits = int(lengths.max())
    # table: every maxbits-bit prefix -> (symbol, length)
    tbl_sym = np.zeros(1 << maxbits, dtype=np.uint8)
    tbl_len = np.zeros(1 << maxbits, dtype=np.uint8)
    for s in np.nonzero(lengths)[0]:
        L = int(lengths[s])
        base = int(codes[s]) << (maxbits - L)
        span = 1 << (maxbits - L)
        tbl_sym[base: base + span] = s
        tbl_len[base: base + span] = L
    bits = np.unpackbits(np.frombuffer(blob[pos:], dtype=np.uint8))
    out = np.empty(n_syms, dtype=np.uint8)
    # Vectorized prefix values: vals[i] = int value of bits[i:i+maxbits].
    # The symbol loop itself stays serial (variable-length decode has a true
    # dependency chain) but each step is just two table lookups.
    pad = np.concatenate([bits, np.zeros(maxbits, dtype=np.uint8)])
    pows = (1 << np.arange(maxbits - 1, -1, -1, dtype=np.uint32))
    vals = np.lib.stride_tricks.sliding_window_view(pad, maxbits)[: bits.size + 1] @ pows
    bitpos = 0
    tl = [int(v) for v in tbl_len]
    ts = tbl_sym
    vlist = vals.tolist()
    for i in range(n_syms):
        w = vlist[bitpos]
        out[i] = ts[w]
        bitpos += tl[w]
    return out.tobytes()
