"""Canonical Huffman coder over byte symbols — N-stream vectorized core.

The entropy stage for ``repro_deflate`` (and its large-window "repro-zstd"
variant).  ZLIB's second pass is Huffman coding (paper §2); this module is a
self-contained, numpy-vectorized encoder with a table-driven decoder so the
paper's "entropy stage" mechanism exists in our from-scratch codec rather
than being inherited opaquely from libz.

Two wire formats, auto-detected by :func:`decode`:

**Legacy 1-stream** (every blob written before the vectorized cores PR;
still produced for small inputs, little-endian ints, MSB-first bits)::

    [2B n_symbols_present][for each present symbol: 1B symbol, then packed
     4-bit code lengths][4B n_encoded_symbols][packed bitstream]

**V2 N-stream container** (zstd Huff0-4X style; DESIGN.md §9).  The input
is split into N chunks of ``ceil(n/N)`` symbols, each chunk coded into its
own byte-aligned bitstream with the *shared* code table, so the decoder can
advance all N streams in lockstep with batched numpy table lookups::

    [2B magic "FH"]        -- LE value 0x4846 > 256, impossible as a legacy
                              n_symbols_present, so detection is exact
    [1B version = 2]
    [1B n_streams]
    [2B n_symbols_present][symbols][packed 4-bit lengths]   (as legacy)
    [4B n_encoded_symbols]
    [4B per-stream bitstream byte length] * n_streams
    [stream bitstreams, concatenated]

Code lengths are capped at 15 bits (deflate's own cap) via the standard
length-limiting fix-up.  Encoders pack bits through a vectorized uint64
bit-accumulator (no per-bit Python work); the V2 decoder gathers all N
stream positions per step, so interpreter overhead amortizes across
streams — which is why, unlike C Huff0's fixed N=4, ``n_streams`` scales
with input size (min 4 for Huff0 parity, more for big baskets).
"""

from __future__ import annotations

import heapq

import numpy as np

__all__ = ["encode", "decode", "code_lengths", "canonical_codes"]

_MAX_BITS = 15

_V2_MAGIC = b"FH"        # LE uint16 0x4846 = 18502 > 256 == max legacy n_present
_V2_VERSION = 2
_V2_MIN_SYMBOLS = 4096   # below this the 1-stream format is smaller & fast enough
_STREAM_CHUNK = 8192     # target symbols per stream (bounds lockstep rounds)
_MIN_STREAMS = 4         # Huff0-4X parity
_MAX_STREAMS = 128


def code_lengths(freqs: np.ndarray) -> np.ndarray:
    """Huffman code lengths (capped at _MAX_BITS) for a 256-entry freq table."""
    sym = np.nonzero(freqs)[0]
    n = sym.size
    lengths = np.zeros(256, dtype=np.uint8)
    if n == 0:
        return lengths
    if n == 1:
        lengths[sym[0]] = 1
        return lengths
    # heap of (freq, tiebreak, node); leaves are ints, internals are tuples
    heap = [(int(freqs[s]), int(s), int(s)) for s in sym]
    heapq.heapify(heap)
    cnt = 256
    while len(heap) > 1:
        f1, _, n1 = heapq.heappop(heap)
        f2, _, n2 = heapq.heappop(heap)
        heapq.heappush(heap, (f1 + f2, cnt, (n1, n2)))
        cnt += 1
    # walk tree for depths
    stack = [(heap[0][2], 0)]
    while stack:
        node, d = stack.pop()
        if isinstance(node, tuple):
            stack.append((node[0], d + 1))
            stack.append((node[1], d + 1))
        else:
            lengths[node] = max(d, 1)
    # length-limit to _MAX_BITS (Kraft fix-up: demote overlong, then re-pay)
    if lengths.max() > _MAX_BITS:
        lengths = np.minimum(lengths, _MAX_BITS)
        # Kraft sum K = sum(2^-len) must be <= 1 in units of 2^-MAX_BITS;
        # demote (lengthen) the rarest symbols until it holds.
        unit = 1 << _MAX_BITS
        k = int(np.sum(unit >> lengths[lengths > 0].astype(np.int64)))
        if k > unit:
            # demote symbols with the smallest freq first
            order = np.argsort(freqs + (lengths == 0) * (1 << 62))
            i = 0
            while k > unit and i < order.size:
                s = order[i]
                while lengths[s] < _MAX_BITS and k > unit:
                    k -= unit >> int(lengths[s])
                    lengths[s] += 1
                    k += unit >> int(lengths[s])
                i += 1
    return lengths


def canonical_codes(lengths: np.ndarray) -> np.ndarray:
    """Canonical code values (uint16) for given code lengths."""
    codes = np.zeros(256, dtype=np.uint16)
    code = 0
    for bits in range(1, _MAX_BITS + 1):
        for s in np.nonzero(lengths == bits)[0]:
            codes[s] = code
            code += 1
        code <<= 1
    return codes


def _pack_bits(symbols: np.ndarray, codes: np.ndarray, lengths: np.ndarray) -> bytes:
    """Pack per-symbol canonical codes MSB-first via uint64 accumulators.

    Each code is left-aligned into a 64-bit lane, shifted to its absolute
    bit offset, and OR-merged per output word with a segmented ``reduceat``
    (codes are emitted in position order, so word indices arrive sorted).
    A code can straddle at most two words (15 < 64), handled by a spill
    pass into word+1.
    """
    lens = lengths[symbols].astype(np.int64)
    total = int(lens.sum())
    if total == 0:
        return b""
    ends = np.cumsum(lens)
    starts = ends - lens
    cv = codes[symbols].astype(np.uint64)
    L = lens.astype(np.uint64)
    w = starts >> 6
    b = (starts & 63).astype(np.uint64)
    top = cv << (np.uint64(64) - L)          # code MSB at word bit 63
    hi = top >> b
    nwords = (total + 63) >> 6
    words = np.zeros(nwords, dtype=np.uint64)

    def _or_segments(idx: np.ndarray, vals: np.ndarray) -> None:
        # idx sorted non-decreasing: OR together runs of equal word index
        first = np.empty(idx.size, dtype=bool)
        first[0] = True
        np.not_equal(idx[1:], idx[:-1], out=first[1:])
        seg = np.flatnonzero(first)
        words[idx[seg]] |= np.bitwise_or.reduceat(vals, seg)

    _or_segments(w, hi)
    spill = (b + L) > np.uint64(64)
    if spill.any():
        bs = b[spill]                        # b >= 50 here, so shifts are < 64
        _or_segments(w[spill] + 1, top[spill] << (np.uint64(64) - bs))
    return words.astype(">u8").tobytes()[: (total + 7) >> 3]


def _table_header(lengths: np.ndarray) -> bytes:
    """[2B n_present][present symbols][packed 4-bit lengths] (both formats)."""
    present = np.nonzero(lengths)[0]
    out = bytearray()
    out += int(present.size).to_bytes(2, "little")
    out += present.astype(np.uint8).tobytes()
    ls = lengths[present]
    if ls.size % 2:
        ls = np.concatenate([ls, [0]])
    out += ((ls[0::2].astype(np.uint8) << 4) | ls[1::2].astype(np.uint8)).tobytes()
    return bytes(out)


def _parse_table(blob: bytes, pos: int) -> tuple[np.ndarray, int]:
    """Invert :func:`_table_header`; returns (lengths[256], next offset)."""
    n_present = int.from_bytes(blob[pos:pos + 2], "little")
    pos += 2
    lengths = np.zeros(256, dtype=np.uint8)
    if n_present == 0:
        return lengths, pos
    present = np.frombuffer(blob, dtype=np.uint8, count=n_present, offset=pos)
    pos += n_present
    n_len_bytes = (n_present + 1) // 2
    packed = np.frombuffer(blob, dtype=np.uint8, count=n_len_bytes, offset=pos)
    pos += n_len_bytes
    ls = np.zeros(n_len_bytes * 2, dtype=np.uint8)
    ls[0::2] = packed >> 4
    ls[1::2] = packed & 0xF
    lengths[present] = ls[:n_present]
    return lengths, pos


def _pick_streams(n_syms: int) -> int:
    if n_syms < _V2_MIN_SYMBOLS:
        return 1
    return max(_MIN_STREAMS, min(_MAX_STREAMS, n_syms // _STREAM_CHUNK))


def encode(data: bytes, n_streams: int | None = None) -> bytes:
    """Huffman-encode a byte string (self-describing header + bitstream).

    ``n_streams=None`` auto-selects: the legacy 1-stream format for small
    inputs, the V2 N-stream container otherwise.  Forcing ``n_streams=1``
    reproduces the legacy wire format byte-identically.
    """
    arr = np.frombuffer(data, dtype=np.uint8)
    if n_streams is None:
        n_streams = _pick_streams(arr.size)
    if not 1 <= n_streams <= 255:
        raise ValueError(f"n_streams must be 1..255, got {n_streams}")
    freqs = np.bincount(arr, minlength=256)
    lengths = code_lengths(freqs)
    codes = canonical_codes(lengths)
    if n_streams == 1:
        out = bytearray(_table_header(lengths))
        out += int(arr.size).to_bytes(4, "little")
        out += _pack_bits(arr, codes, lengths)
        return bytes(out)
    chunk = -(-arr.size // n_streams) if arr.size else 0
    streams = [_pack_bits(arr[s * chunk:(s + 1) * chunk], codes, lengths)
               for s in range(n_streams)]
    out = bytearray(_V2_MAGIC)
    out.append(_V2_VERSION)
    out.append(n_streams)
    out += _table_header(lengths)
    out += int(arr.size).to_bytes(4, "little")
    for s in streams:
        out += len(s).to_bytes(4, "little")
    for s in streams:
        out += s
    return bytes(out)


def _build_table(lengths: np.ndarray) -> tuple[np.ndarray, np.ndarray, int]:
    """(tbl_sym, tbl_len, maxbits): every maxbits-bit prefix -> (symbol, len)."""
    maxbits = int(lengths.max())
    if maxbits == 0:
        raise ValueError("huffman blob has an empty code table but symbols")
    codes = canonical_codes(lengths)
    tbl_sym = np.zeros(1 << maxbits, dtype=np.uint8)
    tbl_len = np.zeros(1 << maxbits, dtype=np.uint8)
    for s in np.nonzero(lengths)[0]:
        L = int(lengths[s])
        base = int(codes[s]) << (maxbits - L)
        span = 1 << (maxbits - L)
        tbl_sym[base: base + span] = s
        tbl_len[base: base + span] = L
    return tbl_sym, tbl_len, maxbits


def _prefix_vals(raw: np.ndarray, maxbits: int) -> np.ndarray:
    """vals[p] = int value of the ``maxbits`` bits starting at bit ``p``.

    Computed per byte through a 24-bit sliding word (8 shifted copies), so
    the whole table costs a few vector passes instead of an 8x unpackbits +
    matmul.
    """
    B = np.concatenate([raw, np.zeros(2, dtype=np.uint8)]).astype(np.uint32)
    w24 = (B[:-2] << np.uint32(16)) | (B[1:-1] << np.uint32(8)) | B[2:]
    shifts = (np.uint32(9) - np.arange(8, dtype=np.uint32))[None, :]
    vals = ((w24[:, None] >> shifts) & np.uint32(0x7FFF)).reshape(-1)
    if maxbits < 15:
        vals >>= np.uint32(15 - maxbits)
    return vals


def _decode_v2(blob: bytes) -> bytes:
    version = blob[2]
    if version != _V2_VERSION:
        raise ValueError(f"unsupported huffman container version {version}")
    n_streams = blob[3]
    lengths, pos = _parse_table(blob, 4)
    n_syms = int.from_bytes(blob[pos:pos + 4], "little")
    pos += 4
    slens = np.frombuffer(blob, dtype="<u4", count=n_streams, offset=pos).astype(np.int64)
    pos += 4 * n_streams
    if n_syms == 0:
        return b""
    tbl_sym, tbl_len, maxbits = _build_table(lengths)
    raw = np.frombuffer(blob, dtype=np.uint8, offset=pos)
    # Total over-advance past the data end is < n_streams lockstep rounds
    # of <= 15 bits each (short tail streams); pad so gathers stay in range.
    pad = 2 * n_streams + 64
    raw = np.concatenate([raw, np.zeros(pad, dtype=np.uint8)])
    vals = _prefix_vals(raw, maxbits)
    bitpos = np.concatenate([[0], np.cumsum(slens)[:-1]]) * 8
    chunk = -(-n_syms // n_streams)
    out = np.empty((chunk, n_streams), dtype=np.uint8)
    tl = tbl_len.astype(np.int64)
    ts = tbl_sym
    # Lockstep: one table-lookup round decodes one symbol from EVERY stream.
    for r in range(chunk):
        w = vals[bitpos]
        out[r] = ts[w]
        bitpos += tl[w]
    # out[r, s] is symbol s*chunk + r; transpose-ravel restores input order
    # and truncation drops the short last stream's garbage tail.
    return out.T.reshape(-1)[:n_syms].tobytes()


def _decode_legacy(blob: bytes) -> bytes:
    """Serial 1-stream decoder (the pre-vectorization path, kept verbatim:
    it is both the legacy-format reader and the perf baseline that
    ``benchmarks/fig_entropy.py`` measures the lockstep core against)."""
    n_present = int.from_bytes(blob[:2], "little")
    if n_present == 0:
        return b""
    lengths, pos = _parse_table(blob, 0)
    n_syms = int.from_bytes(blob[pos:pos + 4], "little")
    pos += 4
    tbl_sym, tbl_len, maxbits = _build_table(lengths)
    bits = np.unpackbits(np.frombuffer(blob, dtype=np.uint8, offset=pos))
    out = np.empty(n_syms, dtype=np.uint8)
    # Vectorized prefix values: vals[i] = int value of bits[i:i+maxbits].
    # The symbol loop itself stays serial (variable-length decode has a true
    # dependency chain) but each step is just two table lookups.
    pad = np.concatenate([bits, np.zeros(maxbits, dtype=np.uint8)])
    pows = (1 << np.arange(maxbits - 1, -1, -1, dtype=np.uint32))
    vals = np.lib.stride_tricks.sliding_window_view(pad, maxbits)[: bits.size + 1] @ pows
    bitpos = 0
    tl = [int(v) for v in tbl_len]
    ts = tbl_sym
    vlist = vals.tolist()
    for i in range(n_syms):
        w = vlist[bitpos]
        out[i] = ts[w]
        bitpos += tl[w]
    return out.tobytes()


def decode(blob: bytes) -> bytes:
    """Invert :func:`encode`; auto-detects the legacy and V2 wire formats."""
    if blob[:2] == _V2_MAGIC:
        return _decode_v2(blob)
    return _decode_legacy(blob)
