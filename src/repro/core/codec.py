"""Codec registry — the paper's §2 algorithm set behind one interface.

Every codec exposes the single tunable the paper describes: an integer
"compression level", 0 = disabled, 1 = fastest … 9 = best ratio.  Each codec
maps that onto its native knob:

=============  =======================================================
``zlib``       stdlib zlib (madler reference — the paper's baseline)
``lz4``        our LZ4 block format; levels 1–3 greedy fast, 4–9 HC
``zstd``       libzstd via ``zstandard``; level l -> zstd level 2l+1
               (so level 9 ~ zstd 19, the practical max)
``zstd-fast``  libzstd negative levels (-1..-9): the C-speed stand-in
               for LZ4-class operating points (see DESIGN.md §4)
``lzma``       stdlib lzma, preset = level; **no dictionary support** —
               FORMAT_XZ has no zdict-style preset-dictionary hook, so
               *compressing* with a dictionary raises ``ValueError``
               rather than silently dropping it (decompression tolerates
               one: files written before this check are plain XZ streams
               and must stay readable)
``repro-deflate``  from-scratch LZ77+Huffman with triplet/quadruplet
               hashing (CF-ZLIB's levels-1–5 mechanism, measurable)
``none``       identity (level 0 semantics for every codec)
=============  =======================================================

Dictionaries (paper §2.3): ``CompressionConfig.dictionary`` carries trained
dictionary bytes.  zstd uses them natively; zlib via ``zdict``; lz4 via
prefix priming (dictionary prepended to the window); lzma rejects them
(see the table above).  See ``repro.core.dictionary`` for training.
"""

from __future__ import annotations

import dataclasses
import lzma
import zlib
from typing import Callable, Optional

from . import lz4 as _lz4
from . import precond as _precond
from . import repro_deflate as _rdef

try:
    import zstandard as _zstd
    HAVE_ZSTD = True
except ImportError:  # pragma: no cover
    HAVE_ZSTD = False

__all__ = ["Codec", "CompressionConfig", "CODECS", "get_codec", "compress",
           "decompress", "decompress_into"]


@dataclasses.dataclass(frozen=True)
class Codec:
    name: str
    compress: Callable  # (data, level, dictionary) -> bytes
    decompress: Callable  # (comp, orig_len, dictionary) -> bytes
    max_level: int = 9
    # True = the codec runs in the Python interpreter and holds the GIL, so
    # thread-level basket parallelism can't scale it; the parallel I/O
    # engine (repro.io.engine) routes such codecs to a process pool instead.
    pure_python: bool = False


# ---------------------------------------------------------------------------
# zlib
# ---------------------------------------------------------------------------

def _zlib_c(data: bytes, level: int, d: Optional[bytes]) -> bytes:
    if d:
        co = zlib.compressobj(level=level, zdict=d)
        return co.compress(data) + co.flush()
    return zlib.compress(data, level)


def _zlib_d(comp: bytes, orig_len: int, d: Optional[bytes]) -> bytes:
    if d:
        do = zlib.decompressobj(zdict=d)
        return do.decompress(comp) + do.flush()
    return zlib.decompress(comp)


# ---------------------------------------------------------------------------
# lz4 (our block format); dictionary = window prefix priming
# ---------------------------------------------------------------------------

def _lz4_c(data: bytes, level: int, d: Optional[bytes]) -> bytes:
    return _lz4.compress_block(data, level, dict_prefix=d or b"")


def _lz4_d(comp: bytes, orig_len: int, d: Optional[bytes]) -> bytes:
    return _lz4.decompress_block(comp, orig_len, dict_prefix=d or b"")


# ---------------------------------------------------------------------------
# zstd (real libzstd) — positive and negative ("fast") level maps
# ---------------------------------------------------------------------------

def _zstd_c(data: bytes, level: int, d: Optional[bytes]) -> bytes:
    zl = min(2 * level + 1, 19)
    kw = {"dict_data": _zstd.ZstdCompressionDict(d)} if d else {}
    return _zstd.ZstdCompressor(level=zl, **kw).compress(data)


def _zstd_d(comp: bytes, orig_len: int, d: Optional[bytes]) -> bytes:
    kw = {"dict_data": _zstd.ZstdCompressionDict(d)} if d else {}
    return _zstd.ZstdDecompressor(**kw).decompress(comp, max_output_size=max(orig_len, 1))


def _zstd_fast_c(data: bytes, level: int, d: Optional[bytes]) -> bytes:
    kw = {"dict_data": _zstd.ZstdCompressionDict(d)} if d else {}
    return _zstd.ZstdCompressor(level=-level, **kw).compress(data)


# ---------------------------------------------------------------------------
# lzma
# ---------------------------------------------------------------------------

def _lzma_c(data: bytes, level: int, d: Optional[bytes]) -> bytes:
    if d:
        raise ValueError(
            "lzma codec does not support trained dictionaries "
            "(FORMAT_XZ has no preset-dictionary mechanism); "
            "use zstd/zlib/lz4 or drop the dictionary")
    return lzma.compress(data, format=lzma.FORMAT_XZ, preset=level)


def _lzma_d(comp: bytes, orig_len: int, d: Optional[bytes]) -> bytes:
    # decompress tolerates a configured dictionary: files written before
    # compression started rejecting it are plain XZ streams (the dict was
    # never used) and must stay readable
    return lzma.decompress(comp, format=lzma.FORMAT_XZ)


# ---------------------------------------------------------------------------
# repro-deflate / repro-zstd — our from-scratch LZ77+Huffman engine.
# repro-deflate: 32 KB window (zlib-like), CF quadruplet hashing.
# repro-deflate-ref: same but reference-zlib triplet hashing (the paper's
#     CF-vs-ref ablation, exposed as a codec so it flows through benchmarks).
# repro-zstd: 256 KB window (the ZSTD window mechanism, §2.3).
# ---------------------------------------------------------------------------

def _rdef_c(data: bytes, level: int, d: Optional[bytes]) -> bytes:
    return _rdef.compress(data, level=level, mode="cf", window_log=15, dictionary=d)


def _rdef_ref_c(data: bytes, level: int, d: Optional[bytes]) -> bytes:
    return _rdef.compress(data, level=level, mode="ref", window_log=15, dictionary=d)


def _rzstd_c(data: bytes, level: int, d: Optional[bytes]) -> bytes:
    return _rdef.compress(data, level=level, mode="cf", window_log=18, dictionary=d)


def _rdef_d(comp: bytes, orig_len: int, d: Optional[bytes]) -> bytes:
    return _rdef.decompress(comp, orig_len, dictionary=d)


# ---------------------------------------------------------------------------
# identity
# ---------------------------------------------------------------------------

def _id_c(data: bytes, level: int, d: Optional[bytes]) -> bytes:
    return data


def _id_d(comp: bytes, orig_len: int, d: Optional[bytes]) -> bytes:
    return comp


CODECS: dict[str, Codec] = {
    "none": Codec("none", _id_c, _id_d, max_level=0),
    "zlib": Codec("zlib", _zlib_c, _zlib_d),
    "lz4": Codec("lz4", _lz4_c, _lz4_d, pure_python=True),
    "lzma": Codec("lzma", _lzma_c, _lzma_d),
    "repro-deflate": Codec("repro-deflate", _rdef_c, _rdef_d, pure_python=True),
    "repro-deflate-ref": Codec("repro-deflate-ref", _rdef_ref_c, _rdef_d,
                               pure_python=True),
    "repro-zstd": Codec("repro-zstd", _rzstd_c, _rdef_d, pure_python=True),
}
if HAVE_ZSTD:
    CODECS["zstd"] = Codec("zstd", _zstd_c, _zstd_d)
    CODECS["zstd-fast"] = Codec("zstd-fast", _zstd_fast_c, _zstd_d)
else:
    # offline fallback: the mechanism-faithful large-window engine stands in
    # for libzstd (DESIGN.md §4); "zstd-fast" maps to low-level large-window.
    CODECS["zstd"] = Codec("zstd", _rzstd_c, _rdef_d, pure_python=True)
    CODECS["zstd-fast"] = Codec("zstd-fast",
                                lambda d, l, dic: _rzstd_c(d, 1, dic), _rdef_d,
                                pure_python=True)


def is_pure_python(algo: str) -> bool:
    """True when ``algo`` can't scale across threads (holds the GIL)."""
    return algo != "none" and get_codec(algo).pure_python


def register_codec(codec: Codec) -> None:
    CODECS[codec.name] = codec


def get_codec(name: str) -> Codec:
    try:
        return CODECS[name]
    except KeyError:
        raise KeyError(f"unknown codec {name!r}; have {sorted(CODECS)}") from None


@dataclasses.dataclass(frozen=True)
class CompressionConfig:
    """Per-branch compression choice — ROOT's (algo, level) plus the paper's
    proposed extensions: a preconditioner pipeline and an optional trained
    dictionary."""

    algo: str = "zstd" if HAVE_ZSTD else "zlib"
    level: int = 5
    precond: str = "none"          # e.g. "bitshuffle4", "delta4+shuffle4"
    dictionary: Optional[bytes] = None

    def __post_init__(self):
        if self.algo != "none":
            get_codec(self.algo)
        if not (0 <= self.level <= 9):
            raise ValueError(f"compression level must be 0..9, got {self.level}")

    @property
    def enabled(self) -> bool:
        return self.level > 0 and self.algo != "none"


def compress(data: bytes, cfg: CompressionConfig) -> bytes:
    """Apply preconditioner pipeline then codec.  Level 0 = passthrough
    (but preconditioning is still applied so roundtrip stays symmetric).

    ``data`` may be any buffer-protocol object (bytes, memoryview,
    contiguous ndarray) — the zero-copy chunks from ``split_array`` flow
    through here without an intermediate ``bytes`` materialization."""
    buf = _precond.apply_precond(cfg.precond, data) if cfg.precond != "none" else data
    if not cfg.enabled:
        return buf
    return get_codec(cfg.algo).compress(buf, cfg.level, cfg.dictionary)


def decompress(comp: bytes, orig_len: int, cfg: CompressionConfig,
               stored_len: Optional[int] = None) -> bytes:
    """Invert :func:`compress`.

    ``orig_len`` is the pre-preconditioner length; ``stored_len`` the
    post-preconditioner (= codec input) length.  They differ only for
    bitshuffle with an element count not divisible by 8 (packbits padding).
    """
    if stored_len is None:
        stored_len = orig_len
    buf = comp if not cfg.enabled else get_codec(cfg.algo).decompress(comp, stored_len, cfg.dictionary)
    if cfg.precond != "none":
        buf = _precond.undo_precond(cfg.precond, buf, orig_len)
    return buf


def decompress_into(comp: bytes, orig_len: int, cfg: CompressionConfig, out,
                    stored_len: Optional[int] = None) -> int:
    """Invert :func:`compress` directly into ``out`` (writable buffer).

    The codec stage still produces an intermediate (none of the entropy
    backends expose a decode-into hook), but the preconditioner inverse —
    or, for ``precond="none"``, the single payload copy — lands in the
    caller's destination, so ``read_branch`` can scatter every basket into
    one preallocated array with no per-basket ``bytes`` and no final
    concatenation.  Returns the number of bytes written."""
    if stored_len is None:
        stored_len = orig_len
    buf = comp if not cfg.enabled else get_codec(cfg.algo).decompress(comp, stored_len, cfg.dictionary)
    return _precond.undo_precond_into(cfg.precond, buf, out, orig_len)
