"""Compression dictionaries (paper §2.3).

ZSTD trains a dictionary from sample buffers; the paper's observation is
that the *same* trained dictionary also helps ZLIB (via ``zdict``) and LZ4
(via window priming) — "the generated dictionaries are useable for ZLIB and
LZ4 as well" (§3).

``train_dictionary`` uses libzstd's COVER trainer when the ``zstandard``
package is present; offline (this container) it falls back to a pure-numpy
frequent-segment trainer implementing the same idea COVER formalizes:
find byte segments that recur across samples and concatenate them,
rarest-first, so the most frequent material sits at the *end* of the
dictionary (closest to the compression window — both zlib's ``zdict`` and
LZ4 prefix priming find near matches cheapest).

``DictPolicy``'s sizing rule answers the paper's open sizing question with
a simple, measurable heuristic (~5% of corpus, clamped), which
``benchmarks/fig_dict.py`` sweeps.
"""

from __future__ import annotations

from collections import Counter
from typing import Iterable, Optional

import numpy as np

try:
    import zstandard as _zstd
    HAVE_ZSTD = True
except ImportError:  # pragma: no cover
    HAVE_ZSTD = False

__all__ = ["train_dictionary", "train_dictionary_numpy", "suggest_dict_size", "HAVE_ZSTD"]


def suggest_dict_size(samples: list[bytes], per_sample_frac: float = 0.05,
                      lo: int = 1 << 10, hi: int = 1 << 17) -> int:
    """Sizing rule: ~5% of total sample bytes, clamped to [1 KiB, 128 KiB].

    Rationale (recorded for the paper's open question): the dictionary is
    stored once per branch in the TOC, amortized over all its baskets, so it
    pays off when dict_size < sum(per-basket savings).  Empirically the
    savings curve flattens near 5% of corpus size for small-buffer corpora
    (see benchmarks/fig_dict.py sweep).
    """
    total = sum(len(s) for s in samples)
    return max(lo, min(hi, int(total * per_sample_frac)))


def train_dictionary_numpy(samples: list[bytes], size: int,
                           seg: int = 16, top_frac: float = 4.0) -> bytes:
    """COVER-style frequent-segment dictionary, pure numpy.

    1. slide a ``seg``-byte window over every sample (stride seg//2),
    2. count segment frequencies across the corpus,
    3. keep segments seen >= 2 times, greedily pack them into ``size`` bytes
       ordered rare->frequent (frequent material ends up nearest the window).
    """
    counts: Counter = Counter()
    stride = max(1, seg // 2)
    for s in samples:
        a = np.frombuffer(s, dtype=np.uint8)
        if a.size < seg:
            counts[bytes(a)] += 1
            continue
        wins = np.lib.stride_tricks.sliding_window_view(a, seg)[::stride]
        for w in wins:
            counts[w.tobytes()] += 1
    repeated = [(c, s) for s, c in counts.items() if c >= 2]
    if not repeated:
        return b"".join(samples)[:size]
    # most frequent last; dedupe overlapping content greedily
    repeated.sort(key=lambda cs: cs[0])
    budget = int(size / max(seg, 1) * top_frac)
    chosen = [s for _, s in repeated[-budget:]]
    out = bytearray()
    seen = set()
    for s in chosen:
        if s in seen:
            continue
        seen.add(s)
        out += s
        if len(out) >= size:
            break
    return bytes(out[-size:]) if len(out) > size else bytes(out)


def train_dictionary(samples: Iterable[bytes], size: Optional[int] = None) -> bytes:
    """Train a dictionary from sample buffers; reusable by zlib/lz4/zstd."""
    samples = [bytes(s) for s in samples if len(s) > 8]
    if not samples:
        return b""
    size = size or suggest_dict_size(samples)
    if len(samples) < 8:
        # too small a corpus for any trainer; raw-content prefix
        return b"".join(samples)[:size]
    if HAVE_ZSTD:  # pragma: no cover - not available offline
        try:
            return _zstd.train_dictionary(size, samples).as_bytes()
        except _zstd.ZstdError:
            pass
    return train_dictionary_numpy(samples, size)
