"""repro.core — the paper's contribution: columnar basket compression.

Codecs (zlib/lz4/lzma/repro-deflate/repro-zstd + dictionaries), Blosc-style
preconditioners, vectorized checksums, the basket/file container, and the
per-branch codec policy.  See DESIGN.md §1-4.
"""

from .codec import (CODECS, CompressionConfig, compress, decompress,
                    decompress_into, get_codec)
from .policy import PROFILES, choose, precond_for_array
from .basket import BasketMeta, pack_basket, unpack_basket, unpack_basket_into
from .bfile import BasketFile, BasketWriter, read_arrays, write_arrays
from .dictionary import train_dictionary, suggest_dict_size

__all__ = [
    "CODECS", "CompressionConfig", "compress", "decompress",
    "decompress_into", "get_codec",
    "PROFILES", "choose", "precond_for_array",
    "BasketMeta", "pack_basket", "unpack_basket", "unpack_basket_into",
    "BasketFile", "BasketWriter", "read_arrays", "write_arrays",
    "train_dictionary", "suggest_dict_size",
]
