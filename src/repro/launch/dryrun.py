import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: prove the distribution config is coherent.

For every (architecture x input shape) cell, on the single-pod 16x16 mesh
AND the 2-pod (2,16,16) mesh:

    with mesh:
        lowered = jax.jit(step, in_shardings=..., out_shardings=...).lower(
            *input_specs(...))
        compiled = lowered.compile()
        compiled.memory_analysis()       # proves it fits per device
        compiled.cost_analysis()         # FLOPs / bytes for the roofline

plus a trip-count-aware HLO cost walk (launch/hlo_cost.py).  Results land as
JSON in artifacts/dryrun/ (read by benchmarks/roofline.py) and a summary
line prints per cell.  Any failure here (sharding mismatch, OOM at
compile, unsupported collective) is a bug in the framework.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all \
        --mesh both [--out artifacts/dryrun]
"""

import argparse
import json
import time
import traceback

import jax

from repro.configs import get_config, list_archs, shapes_for, SHAPES
from repro.launch.hlo_cost import analyze_hlo
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import (active_params, build_cell, parallelism_for,
                                total_params)

V5E = {"flops_bf16": 197e12, "hbm_gbps": 819e9, "ici_gbps": 50e9}


PERF_KEYS = ("rms_einsum", "softmax_bf16_probs", "mamba_bf16_y", "bf16_grads",
             "compressed_tp")


def set_perf_flags(names: list[str]) -> dict:
    """Toggle §Perf variants; returns train_kwargs additions."""
    from repro.models import layers as L, ssm as S, rwkv as R
    L.PERF_FLAGS["rms_einsum"] = "rms_einsum" in names
    L.PERF_FLAGS["softmax_bf16_probs"] = "softmax_bf16_probs" in names
    S.PERF_FLAGS["mamba_bf16_y"] = "mamba_bf16_y" in names
    R.PERF_FLAGS["compressed_tp"] = "compressed_tp" in names
    return {"bf16_grads": True} if "bf16_grads" in names else {}


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             pcfg_overrides: dict | None = None,
             train_kwargs: dict | None = None) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    pcfg = parallelism_for(cfg)
    if pcfg_overrides:
        import dataclasses
        pcfg = dataclasses.replace(pcfg, **pcfg_overrides)
    cell = build_cell(cfg, shape, mesh, pcfg, train_kwargs=train_kwargs)

    from repro.parallel.actctx import activation_context
    t0 = time.monotonic()
    with mesh, activation_context(mesh):
        jitted = jax.jit(cell.fn, in_shardings=cell.in_shardings,
                         out_shardings=cell.out_shardings,
                         donate_argnums=cell.donate_argnums)
        lowered = jitted.lower(*cell.args)
        t_lower = time.monotonic() - t0
        compiled = lowered.compile()
        t_compile = time.monotonic() - t0 - t_lower

    mem = compiled.memory_analysis()
    xla_cost = compiled.cost_analysis() or {}
    # trip-count-aware walk (xla cost_analysis counts loop bodies ONCE —
    # useless under scan-over-layers; see launch/hlo_cost.py)
    cost = analyze_hlo(compiled.as_text())

    n_dev = mesh.devices.size
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    n_active = active_params(cfg)
    model_flops = (6 if shape.kind == "train" else 2) * n_active * tokens

    flops_dev = cost.flops
    bytes_dev = cost.bytes
    wire_dev = cost.coll_wire

    rec = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "devices": int(n_dev),
        "kind": shape.kind,
        "seq_len": shape.seq_len, "global_batch": shape.global_batch,
        "params_total": int(total_params(cfg)),
        "params_active": int(n_active),
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "per_device": {
            "hlo_flops": flops_dev,
            "hlo_bytes": bytes_dev,
            "collective_wire_bytes": wire_dev,
            "arg_bytes": mem.argument_size_in_bytes,
            "out_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "peak_bytes": (mem.argument_size_in_bytes
                           + mem.output_size_in_bytes
                           + mem.temp_size_in_bytes
                           - mem.alias_size_in_bytes),
        },
        "collectives": {"per_kind": cost.per_kind,
                        "total": {"count": cost.coll_count,
                                  "payload_bytes": cost.coll_payload,
                                  "wire_bytes": cost.coll_wire},
                        "unknown_trip_loops": cost.unknown_loops},
        "xla_flops_once": float(xla_cost.get("flops", 0.0)),
        "model_flops_global": float(model_flops),
        "roofline_s": {
            "compute": flops_dev / V5E["flops_bf16"],
            "memory": bytes_dev / V5E["hbm_gbps"],
            "collective": wire_dev / V5E["ici_gbps"],
        },
    }
    terms = rec["roofline_s"]
    rec["bottleneck"] = max(terms, key=terms.get)
    rec["mfu_vs_roofline"] = (
        (model_flops / n_dev / V5E["flops_bf16"]) / max(max(terms.values()), 1e-30))
    return rec


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--tag", default="", help="artifact filename suffix (perf variants)")
    ap.add_argument("--accum", type=int, default=0, help="override gradient-accumulation count")
    ap.add_argument("--perf", default="",
                    help=f"comma list of perf variants: {','.join(PERF_KEYS)}")
    args = ap.parse_args()

    perf_names = [n for n in args.perf.split(",") if n]
    extra_train_kwargs = set_perf_flags(perf_names)
    if args.accum:
        extra_train_kwargs["accum"] = args.accum
        if not args.tag:
            args.tag = f"__accum{args.accum}"
    if perf_names and not args.tag:
        args.tag = "__perf-" + "-".join(perf_names)

    archs = list_archs() if args.arch == "all" else [args.arch]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    os.makedirs(args.out, exist_ok=True)

    failures = []
    for arch in archs:
        cfg = get_config(arch)
        shapes = [s.name for s in shapes_for(cfg)]
        if args.shape != "all":
            if args.shape not in shapes:
                print(f"-- {arch} {args.shape}: not assigned (skipped)")
                continue
            shapes = [args.shape]
        for sname in shapes:
            for mp in meshes:
                mesh_tag = "2x16x16" if mp else "16x16"
                try:
                    rec = run_cell(arch, sname, mp,
                                   train_kwargs=extra_train_kwargs or None)
                except Exception as e:
                    failures.append((arch, sname, mesh_tag, e))
                    print(f"FAIL {arch} {sname} {mesh_tag}: {e}")
                    traceback.print_exc()
                    continue
                fn = f"{arch}__{sname}__{mesh_tag}{args.tag}.json"
                with open(os.path.join(args.out, fn), "w") as fh:
                    json.dump(rec, fh, indent=1)
                t = rec["roofline_s"]
                print(f"OK {arch:26s} {sname:12s} {mesh_tag:8s} "
                      f"compile={rec['compile_s']:6.1f}s "
                      f"peak={rec['per_device']['peak_bytes']/2**30:6.2f}GiB "
                      f"compute={t['compute']*1e3:8.2f}ms "
                      f"mem={t['memory']*1e3:8.2f}ms "
                      f"coll={t['collective']*1e3:8.2f}ms "
                      f"-> {rec['bottleneck']}", flush=True)
    if failures:
        print(f"\n{len(failures)} FAILURES")
        for f in failures:
            print("  ", *f[:3], repr(f[3])[:200])
        return 1
    print("\nall dry-run cells passed")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
