"""Per-(arch x shape) step builders for the dry-run and the drivers.

``input_specs(cfg, shape)`` returns weak-type-correct ShapeDtypeStruct
stand-ins for every model input — no allocation; ``build_cell`` wires a
step function + abstract args + in/out NamedShardings for one
(arch, shape, mesh) cell, ready for ``jax.jit(...).lower(...)``.

Shape semantics (assignment block):
  train_4k     train_step  (tokens+targets, global_batch x seq)
  prefill_32k  prefill     (prompt batch -> logits + built cache)
  decode_32k   decode_step (1 new token against a seq_len KV cache)
  long_500k    decode_step (ssm/hybrid archs only — sub-quadratic state)

Modality stubs per the assignment: [audio] enc-dec takes precomputed frame
embeddings (B, S, d); [vlm] takes precomputed patch embeddings (B, 256, d).
For the VLM, "seq_len" counts the full context (patches + text).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ShapeSpec
from repro.models import Model, ModelConfig
from repro.models.specs import tree_paths
from repro.parallel import (ParallelismConfig, param_shardings,
                            batch_shardings, cache_shardings, opt_shardings)
from repro.train.step import (TrainState, make_train_step,
                              abstract_train_state)

__all__ = ["input_specs", "build_cell", "parallelism_for", "total_params",
           "active_params", "SEAMLESS_DEC_PROMPT", "SEAMLESS_CROSS_LEN"]

SEAMLESS_DEC_PROMPT = 256     # decoder prompt length for enc-dec prefill
SEAMLESS_CROSS_LEN = 4096     # encoder context length for enc-dec decode


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def total_params(cfg: ModelConfig) -> int:
    flat = tree_paths(Model(cfg).param_specs())
    n = 0
    for spec in flat.values():
        k = 1
        for d in spec.shape:
            k *= d
        n += k
    return n


def active_params(cfg: ModelConfig) -> int:
    """Per-token active params: expert tensors count K/E of their size."""
    flat = tree_paths(Model(cfg).param_specs())
    n = 0
    for path, spec in flat.items():
        k = 1
        for d in spec.shape:
            k *= d
        if "experts" in spec.axes:
            k = k * cfg.experts_per_token // max(cfg.n_experts, 1)
        n += k
    return n


def parallelism_for(cfg: ModelConfig, compressed_dp: bool = False) -> ParallelismConfig:
    big = True  # FSDP always on at 256+ chips: replicated fp32 masters never fit
    return ParallelismConfig(zero3=big, zero1_moments=True,
                             shard_kv_cache_time=True, experts_fsdp=True,
                             compressed_dp=compressed_dp)


# ---------------------------------------------------------------------------
# abstract inputs
# ---------------------------------------------------------------------------

def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    """Abstract batch for train/prefill kinds (decode builds cache too)."""
    B, S = shape.global_batch, shape.seq_len
    it = jnp.int32
    if shape.kind == "train":
        if cfg.is_encdec:
            return {"frames": _sds((B, S, cfg.d_model), jnp.float32),
                    "tokens": _sds((B, S), it), "targets": _sds((B, S), it)}
        if cfg.n_img_tokens:
            st = S - cfg.n_img_tokens
            return {"patches": _sds((B, cfg.n_img_tokens, cfg.d_model), jnp.float32),
                    "tokens": _sds((B, st), it), "targets": _sds((B, st), it)}
        return {"tokens": _sds((B, S), it), "targets": _sds((B, S), it)}
    if shape.kind == "prefill":
        if cfg.is_encdec:
            return {"frames": _sds((B, S, cfg.d_model), jnp.float32),
                    "tokens": _sds((B, SEAMLESS_DEC_PROMPT), it)}
        if cfg.n_img_tokens:
            return {"patches": _sds((B, cfg.n_img_tokens, cfg.d_model), jnp.float32),
                    "tokens": _sds((B, S - cfg.n_img_tokens), it)}
        return {"tokens": _sds((B, S), it)}
    # decode: one new token
    return {"tokens": _sds((B, 1), it)}


# ---------------------------------------------------------------------------
# cells
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Cell:
    fn: Any
    args: tuple
    in_shardings: Any
    out_shardings: Any
    donate_argnums: tuple = ()
    static_argnums: tuple = ()


def _metrics_sharding(mesh):
    return NamedSharding(mesh, P())


def default_accum(cfg: ModelConfig, shape: ShapeSpec, mesh) -> int:
    """Microbatch count so the per-device residual-carry memory (the
    scan-over-groups activation saves, B_loc*S*d*2B*n_groups) stays under
    ~6 GiB — the napkin-math knob that keeps every train cell inside v5e
    HBM (EXPERIMENTS.md §Dry-run)."""
    dp = 1
    for a in mesh.axis_names:
        if a != "model":
            dp *= mesh.shape[a]
    b_loc = max(shape.global_batch // dp, 1)
    resid = b_loc * shape.seq_len * cfg.d_model * 2 * cfg.n_groups
    for accum in (1, 2, 4, 8):
        if resid / accum <= 6 * 2**30 and (shape.global_batch // dp) % accum == 0:
            return accum
    return 8


def build_cell(cfg: ModelConfig, shape: ShapeSpec, mesh,
               pcfg: ParallelismConfig | None = None,
               train_kwargs: dict | None = None) -> Cell:
    # flash-style query chunking for any long-context full pass
    if shape.kind in ("train", "prefill") and shape.seq_len >= 2048 and not cfg.q_chunk:
        cfg = dataclasses.replace(cfg, q_chunk=256 if shape.kind == "train" else 512)
    model = Model(cfg)
    pcfg = pcfg or parallelism_for(cfg)
    batch = input_specs(cfg, shape)
    rep = NamedSharding(mesh, P())

    if shape.kind == "train":
        big = total_params(cfg) >= 200e9
        kwargs = dict(bf16_moments=big, accum=default_accum(cfg, shape, mesh))
        kwargs.update(train_kwargs or {})
        accum = kwargs["accum"]
        if accum > 1:   # batch leaves become (accum, micro, ...)
            batch = {k: _sds((accum, v.shape[0] // accum) + v.shape[1:], v.dtype)
                     for k, v in batch.items()}
        step = make_train_step(model, **kwargs)
        state = abstract_train_state(model, bf16_moments=kwargs["bf16_moments"],
                                     compress_grads=kwargs.get("compress_grads", False))
        psh = param_shardings(model, mesh, pcfg)
        osh = opt_shardings(model, mesh, pcfg)
        state_sh = TrainState(
            params=psh,
            opt={"m": osh, "v": osh, "count": rep},
            step=rep,
            err=psh if state.err is not None else None)
        if accum > 1:
            from repro.parallel.sharding import dp_spec
            bsh = {k: NamedSharding(
                mesh, P(None, dp_spec(mesh, v.shape[1]),
                        *([None] * (len(v.shape) - 2))))
                for k, v in batch.items()}
        else:
            bsh = batch_shardings(mesh, batch)
        return Cell(fn=step, args=(state, batch),
                    in_shardings=(state_sh, bsh),
                    out_shardings=(state_sh, _metrics_sharding(mesh)),
                    donate_argnums=(0,))

    from repro.parallel.sharding import dp_spec
    params = model.abstract(dtype=jnp.bfloat16)
    psh = param_shardings(model, mesh, pcfg)
    dp = dp_spec(mesh, shape.global_batch)
    logits_sh = NamedSharding(mesh, P(dp, None))

    if shape.kind == "prefill":
        S_ctx = shape.seq_len if not cfg.is_encdec else SEAMLESS_DEC_PROMPT
        fn = lambda p, b: model.prefill(p, b, max_len=S_ctx)
        cache_abs = model.init_cache(
            shape.global_batch, S_ctx,
            enc_len=shape.seq_len if cfg.is_encdec else 0, abstract=True)
        csh = cache_shardings(model, mesh, pcfg, cache_abs)
        bsh = batch_shardings(mesh, batch)
        return Cell(fn=fn, args=(params, batch),
                    in_shardings=(psh, bsh),
                    out_shardings=(logits_sh, csh))

    # decode
    cache_abs = model.init_cache(
        shape.global_batch, shape.seq_len,
        enc_len=SEAMLESS_CROSS_LEN if cfg.is_encdec else 0, abstract=True)
    csh = cache_shardings(model, mesh, pcfg, cache_abs)
    tok = batch["tokens"]
    tok_sh = NamedSharding(mesh, P(dp, None))
    pos = _sds((), jnp.int32)
    fn = model.decode_step
    return Cell(fn=fn, args=(params, cache_abs, tok, pos),
                in_shardings=(psh, csh, tok_sh, rep),
                out_shardings=(logits_sh, csh),
                donate_argnums=(1,))
