"""Production meshes.  Functions, not module constants, so importing this
module never touches jax device state (device count is locked on first use).

Single pod: (16, 16) = 256 chips over ("data", "model").
Multi-pod:  (2, 16, 16) = 512 chips over ("pod", "data", "model") — the
"pod" axis is pure data parallelism across ICI-connected pods (DCN in a
real deployment; the dry-run proves the sharding is coherent either way).
"""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_host_mesh", "dp_axes", "TP_AXIS"]

TP_AXIS = "model"


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Tiny mesh over whatever devices exist (CPU smoke / examples)."""
    n = len(jax.devices())
    data = min(data, n)
    model = max(min(model, n // data), 1)
    return jax.make_mesh((data, model), ("data", "model"))


def dp_axes(mesh) -> tuple:
    """The data-parallel mesh axes (everything except the TP axis)."""
    return tuple(a for a in mesh.axis_names if a != TP_AXIS)
