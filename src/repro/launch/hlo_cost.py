"""Trip-count-aware HLO cost walker.

XLA's ``compiled.cost_analysis()`` counts every while-loop body ONCE —
under scan-over-layers + gradient-accumulation + chunked attention that
undercounts FLOPs/bytes by 100x+ (measured), and the same bug applies to
any naive collective inventory.  This walker parses the optimized SPMD
module text and:

  * multiplies loop bodies by ``known_trip_count`` (XLA records it in
    backend_config for counted loops; unknown loops default to 1 and are
    reported),
  * counts dot FLOPs exactly (2 * result_elems * contraction size, shapes
    resolved through a per-computation symbol table),
  * models post-fusion HBM traffic: one fusion = operands + results once
    (closer to real traffic than per-op "bytes accessed"),
  * sums collective wire bytes with ring factors x trip counts.

Used by launch.dryrun for the roofline terms (§Roofline).
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field

__all__ = ["analyze_hlo", "Cost"]

DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COMP_RE = re.compile(r"^(?:ENTRY )?%?([\w.\-]+) \(.*\) -> .+ \{\s*$")
_INST_RE = re.compile(
    r"^\s+(ROOT )?%([\w.\-]+) = ((?:\([^)]*\))|(?:[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?))\s+"
    r"([a-z0-9\-]+)\((.*)$")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_GROUPS_ITOA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")

_NO_TRAFFIC = {"parameter", "constant", "tuple", "get-tuple-element",
               "bitcast", "bitcast-convert", "copy", "after-all",
               "opt-barrier", "partition-id", "replica-id", "iota",
               "get-dimension-size"}
_COLLECTIVES = {"all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute", "all-reduce-start", "all-gather-start",
                "collective-permute-start"}


def _elems_and_bytes(type_str: str) -> tuple[int, int]:
    elems = tot = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        elems += n
        tot += n * DTYPE_BYTES[dt]
    return elems, tot


@dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll_wire: float = 0.0
    coll_payload: float = 0.0
    coll_count: float = 0.0
    per_kind: dict = field(default_factory=dict)
    unknown_loops: int = 0

    def add(self, other: "Cost", mult: float = 1.0):
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        self.coll_wire += other.coll_wire * mult
        self.coll_payload += other.coll_payload * mult
        self.coll_count += other.coll_count * mult
        self.unknown_loops += other.unknown_loops
        for k, v in other.per_kind.items():
            e = self.per_kind.setdefault(k, {"count": 0.0, "payload_bytes": 0.0,
                                             "wire_bytes": 0.0})
            for f in e:
                e[f] += v[f] * mult


@dataclass
class _Inst:
    name: str
    type_str: str
    op: str
    tail: str          # operand list + attributes (rest of line)
    is_root: bool = False


def _parse_computations(hlo: str) -> tuple[dict, str]:
    comps: dict[str, list[_Inst]] = {}
    entry = None
    cur = None
    for line in hlo.splitlines():
        m = _COMP_RE.match(line)
        if m:
            cur = m.group(1)
            comps[cur] = []
            if line.startswith("ENTRY"):
                entry = cur
            continue
        if cur is None:
            continue
        if line.startswith("}"):
            cur = None
            continue
        mi = _INST_RE.match(line)
        if mi:
            comps[cur].append(_Inst(mi.group(2), mi.group(3), mi.group(4),
                                    mi.group(5), bool(mi.group(1))))
    return comps, entry


def _group_size(tail: str) -> int:
    m = _GROUPS_ITOA_RE.search(tail)
    if m:
        return max(int(m.group(2)), 1)
    m = _GROUPS_LIST_RE.search(tail)
    if m:
        return max(len(m.group(1).split(",")), 1)
    return 1


def _dot_flops(inst: _Inst, symtab: dict) -> float:
    res_elems, _ = _elems_and_bytes(inst.type_str)
    mo = re.match(r"%([\w.\-]+), %([\w.\-]+)\)", inst.tail)
    k = 1
    mc = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", inst.tail)
    if mo and mc and mc.group(1):
        lhs_type = symtab.get(mo.group(1), "")
        dims_m = _SHAPE_RE.search(lhs_type)
        if dims_m:
            dims = [int(d) for d in dims_m.group(2).split(",") if d]
            for ci in mc.group(1).split(","):
                ci = int(ci)
                if ci < len(dims):
                    k *= dims[ci]
    return 2.0 * res_elems * k


def _operand_bytes(inst: _Inst, symtab: dict) -> int:
    tot = 0
    # operands are %refs before the closing paren of the op
    op_part = inst.tail.split(")")[0]
    for ref in re.findall(r"%([\w.\-]+)", op_part):
        if ref in symtab:
            _, b = _elems_and_bytes(symtab[ref])
            tot += b
    return tot


_SLICE_OPS = {"dynamic-slice", "slice", "gather"}


def _fusion_io_bytes(inst: _Inst, symtab: dict, fname: str,
                     comps: dict) -> tuple[int, int | None]:
    """(operand_read_bytes, result_write_override).

    * a parameter consumed ONLY through slice-like ops inside the fused
      computation is charged at the slice size, not the full (possibly
      scan-stacked) operand;
    * a fusion whose ROOT is an in-place dynamic-update-slice writes only
      the update region, not the whole buffer."""
    finsts = comps.get(fname, [])
    res_override = None
    fsym = {fi.name: fi.type_str for fi in finsts}
    root = next((fi for fi in finsts if fi.is_root),
                finsts[-1] if finsts else None)
    if root is not None and root.op == "dynamic-update-slice":
        refs = re.findall(r"%([\w.\-]+)", root.tail.split(")")[0])
        if len(refs) >= 2 and refs[1] in fsym:
            _, ub = _elems_and_bytes(fsym[refs[1]])
            res_override = ub
    # parameter index -> instruction name, and per-instruction consumers
    # parameter index -> instruction name, and per-instruction consumers
    params = {}
    for fi in finsts:
        if fi.op == "parameter":
            mo = re.match(r"(\d+)\)", fi.tail)
            if mo:
                params[fi.name] = int(mo.group(1))
    sliced_charge: dict[int, int] = {}
    full_needed: set[int] = set()
    for fi in finsts:
        if fi.op == "parameter":
            continue
        op_part = fi.tail.split(")")[0]
        refs = re.findall(r"%([\w.\-]+)", op_part)
        for r in refs:
            if r in params:
                idx = params[r]
                if fi.op in _SLICE_OPS:
                    _, rb = _elems_and_bytes(fi.type_str)
                    sliced_charge[idx] = sliced_charge.get(idx, 0) + rb
                else:
                    full_needed.add(idx)
    # operand refs in call order = parameter order
    op_part = inst.tail.split(")")[0]
    refs = re.findall(r"%([\w.\-]+)", op_part)
    total = 0
    for idx, r in enumerate(refs):
        if r not in symtab:
            continue
        _, full = _elems_and_bytes(symtab[r])
        if res_override is not None and idx == 0 and idx not in full_needed:
            continue    # in-place DUS target: not read
        if idx in full_needed or idx not in sliced_charge:
            total += full
        else:
            total += min(sliced_charge[idx], full)
    return total, res_override


def _comp_cost(name: str, comps: dict, cache: dict, depth: int = 0) -> Cost:
    if name in cache:
        return cache[name]
    cost = Cost()
    insts = comps.get(name, [])
    symtab = {i.name: i.type_str for i in insts}
    for inst in insts:
        op = inst.op
        if op == "while":
            mt = _TRIP_RE.search(inst.tail)
            trips = int(mt.group(1)) if mt else 1
            if not mt:
                cost.unknown_loops += 1
            mb = _BODY_RE.search(inst.tail)
            mc = _COND_RE.search(inst.tail)
            if mb:
                cost.add(_comp_cost(mb.group(1), comps, cache, depth + 1), trips)
            if mc:
                cost.add(_comp_cost(mc.group(1), comps, cache, depth + 1), trips)
            continue
        if op in ("call", "conditional"):
            for cm in _CALLS_RE.finditer(inst.tail):
                cost.add(_comp_cost(cm.group(1), comps, cache, depth + 1))
            continue
        if op in _COLLECTIVES:
            kind = op.replace("-start", "")
            _, size = _elems_and_bytes(inst.type_str)
            k = _group_size(inst.tail) if kind != "collective-permute" else 2
            if k <= 1 and kind != "collective-permute":
                continue
            if kind == "all-gather":
                wire = size * (k - 1) / k
            elif kind == "all-reduce":
                wire = 2.0 * size * (k - 1) / k
            elif kind == "reduce-scatter":
                wire = float(size) * (k - 1)
            elif kind == "all-to-all":
                wire = size * (k - 1) / k
            else:
                wire = float(size)
            cost.coll_wire += wire
            cost.coll_payload += size
            cost.coll_count += 1
            e = cost.per_kind.setdefault(kind, {"count": 0.0, "payload_bytes": 0.0,
                                                "wire_bytes": 0.0})
            e["count"] += 1
            e["payload_bytes"] += size
            e["wire_bytes"] += wire
            # collective moves bytes through HBM too
            _, rb = _elems_and_bytes(inst.type_str)
            cost.bytes += rb + _operand_bytes(inst, symtab)
            continue
        if op in _NO_TRAFFIC:
            continue
        if op == "fusion":
            # dots inside fusions still count as flops
            fm = _CALLS_RE.search(inst.tail)
            res_elems, res_bytes = _elems_and_bytes(inst.type_str)
            cost.flops += res_elems          # ~1 flop/output element
            if fm:
                sub = _comp_cost(fm.group(1), comps, cache, depth + 1)
                cost.flops += sub.flops
                opb, res_override = _fusion_io_bytes(
                    inst, symtab, fm.group(1), comps)
                cost.bytes += (res_override if res_override is not None
                               else res_bytes) + opb
            else:
                cost.bytes += res_bytes + _operand_bytes(inst, symtab)
            continue
        res_elems, res_bytes = _elems_and_bytes(inst.type_str)
        if op == "dot":
            cost.flops += _dot_flops(inst, symtab)
        elif op in ("convolution",):
            cost.flops += 2.0 * res_elems    # no convs in this framework
        else:
            cost.flops += res_elems
        # traffic model: slice-like ops touch only the slice, and an
        # in-place dynamic-update-slice touches only the update region —
        # charging the whole operand would bill a scan's stacked weights
        # once per iteration (measured 100x inflation).
        if op in ("dynamic-slice", "slice", "gather", "reshape", "transpose",
                  "broadcast", "convert", "reverse", "pad"):
            cost.bytes += 2 * res_bytes
            continue
        if op in ("dynamic-update-slice", "scatter"):
            op_part = inst.tail.split(")")[0]
            refs = re.findall(r"%([\w.\-]+)", op_part)
            upd = 0
            if len(refs) >= 2 and refs[1] in symtab:
                _, upd = _elems_and_bytes(symtab[refs[1]])
            cost.bytes += 3 * upd if op == "scatter" else 2 * upd
            continue
        cost.bytes += res_bytes + _operand_bytes(inst, symtab)
    cache[name] = cost
    return cost


def analyze_hlo(hlo_text: str) -> Cost:
    comps, entry = _parse_computations(hlo_text)
    if entry is None:
        return Cost()
    cache: dict = {}
    # fusion sub-computations must not double count as standalone comps:
    # _comp_cost is called only from the entry walk, so that's guaranteed.
    return _comp_cost(entry, comps, cache)
