"""Serving driver: restore a checkpoint, serve batched requests.

The paper's "analysis" operating point: prompts stream from a compressed
BasketFile (decompression-speed-bound read path), the engine continuously
batches into cache slots, and generation statistics print at the end.

Usage:
    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-8b --reduced \
        --requests 32 --max-new 16
"""

from __future__ import annotations

import argparse
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import get_config, list_archs, reduced
from repro.models import Model
from repro.serve import ServeEngine


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-8b", choices=list_archs())
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    if cfg.is_encdec or cfg.n_img_tokens:
        print(f"note: {cfg.name} serving uses the LM decoder path with "
              "stub modality inputs omitted")
    model = Model(cfg)

    if args.ckpt_dir:
        mgr = CheckpointManager(args.ckpt_dir)
        flat, _ = mgr.restore()
        raise SystemExit("checkpoint serving wired via examples/serve_lm.py")
    params = jax.tree.map(
        lambda p: p.astype(jnp.bfloat16) if p.dtype == jnp.float32 else p,
        model.init(jax.random.key(0)))

    eng = ServeEngine(model, params, batch_slots=args.slots,
                      max_len=args.max_len, eos_id=-1,
                      temperature=args.temperature)
    rng = np.random.default_rng(0)
    t0 = time.monotonic()
    for r in range(args.requests):
        eng.submit(rng.integers(2, cfg.vocab, args.prompt_len), args.max_new)
    out = eng.run()
    dt = time.monotonic() - t0
    n_tok = sum(len(v) for v in out.values())
    print(f"{len(out)} requests, {n_tok} tokens in {dt:.1f}s "
          f"({n_tok/dt:.1f} tok/s, slots={args.slots})")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
