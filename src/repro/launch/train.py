"""End-to-end training driver.

Wires every subsystem together: config registry -> model -> data pipeline
(compressed BasketFile shards) -> sharded train step -> checkpoint manager
(async, atomic, compressed) -> restart/resume.  On this CPU container it
runs reduced configs (--reduced); on a real cluster the same driver takes
the full config + production mesh.

Fault-tolerance drill (exercised by tests/test_train_driver.py):
  * kill the process at any step; re-running resumes from the latest
    checkpoint INCLUDING the data-pipeline cursor — no token skew;
  * --simulate-preempt N exits abruptly after N steps to make that drill
    reproducible.

Usage:
    PYTHONPATH=src python -m repro.launch.train --arch qwen3-8b --reduced \
        --steps 200 --workdir /tmp/run1
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import get_config, list_archs, reduced
from repro.data import TokenPipeline, write_token_shards
from repro.launch.mesh import make_host_mesh
from repro.models import Model
from repro.train import init_train_state, make_train_step
from repro.train.step import TrainState


def build_batch(cfg, raw, accum: int):
    """numpy pipeline batch -> model batch (adds modality stubs)."""
    b = {k: jnp.asarray(v) for k, v in raw.items()}
    B, S = b["tokens"].shape
    if cfg.is_encdec:
        b["frames"] = jnp.ones((B, min(S, 64), cfg.d_model), jnp.float32) * 0.01
    if cfg.n_img_tokens:
        b["patches"] = jnp.ones((B, cfg.n_img_tokens, cfg.d_model), jnp.float32) * 0.01
    if accum > 1:
        b = {k: v.reshape((accum, B // accum) + v.shape[1:]) for k, v in b.items()}
    return b


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-8b", choices=list_archs())
    ap.add_argument("--reduced", action="store_true",
                    help="CPU-scale config (same structure)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--workdir", default="/tmp/repro_train")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--n-shards", type=int, default=4)
    ap.add_argument("--host-id", type=int, default=0)
    ap.add_argument("--n-hosts", type=int, default=1)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--simulate-preempt", type=int, default=0,
                    help="exit(17) after N steps (fault-tolerance drill)")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    model = Model(cfg)

    # ---- data: write shards once, stream with restart cursor
    os.makedirs(args.workdir, exist_ok=True)
    shard_dir = os.path.join(args.workdir, "data")
    shards = [os.path.join(shard_dir, f"shard-{i:03d}.bskt")
              for i in range(args.n_shards)]
    if not all(os.path.exists(p) for p in shards):
        write_token_shards(
            shards, vocab=cfg.vocab,
            tokens_per_shard=max((args.seq_len + 1) * args.batch * 32, 20000))
    pipe = TokenPipeline(shards, batch=args.batch, seq_len=args.seq_len,
                         host_id=args.host_id, n_hosts=args.n_hosts)

    # ---- state: fresh or resumed (elastic: works across device counts)
    mgr = CheckpointManager(os.path.join(args.workdir, "ckpt"), keep=2)
    state = init_train_state(model, jax.random.key(0),
                             compress_grads=args.compress_grads)
    start_step = 0
    if mgr.latest_step() is not None:
        tmpl = {"params": state.params, "opt": state.opt, "step": state.step,
                "err": state.err}
        tree, meta = mgr.restore(template=tmpl)
        state = TrainState(params=tree["params"], opt=tree["opt"],
                           step=jnp.asarray(tree["step"]), err=tree["err"])
        if "data_cursor" in meta:
            pipe.load_state_dict(meta["data_cursor"])
        start_step = int(tree["step"])
        print(f"resumed from step {start_step} (cursor {meta.get('data_cursor')})")

    step_fn = jax.jit(make_train_step(
        model, peak_lr=args.lr, warmup=max(args.steps // 20, 5),
        total_steps=args.steps, accum=args.accum,
        compress_grads=args.compress_grads))

    log_path = os.path.join(args.workdir, "train_log.jsonl")
    t0 = time.monotonic()
    toks_done = 0
    with open(log_path, "a") as logf:
        for i in range(start_step, args.steps):
            raw = next(pipe)
            batch = build_batch(cfg, raw, args.accum)
            state, metrics = step_fn(state, batch)
            toks_done += args.batch * args.seq_len
            if (i + 1) % args.log_every == 0 or i + 1 == args.steps:
                m = {k: float(v) for k, v in metrics.items()}
                m.update(step=i + 1,
                         tok_per_s=toks_done / (time.monotonic() - t0))
                logf.write(json.dumps(m) + "\n")
                logf.flush()
                print(f"step {i+1:5d} loss={m['loss']:.4f} "
                      f"acc={m['accuracy']:.3f} {m['tok_per_s']:.0f} tok/s")
            if (i + 1) % args.ckpt_every == 0 or i + 1 == args.steps:
                tree = {"params": state.params, "opt": state.opt,
                        "step": state.step, "err": state.err}
                mgr.save(i + 1, tree,
                         extra_meta={"data_cursor": pipe.state_dict(),
                                     "arch": cfg.name})
            if args.simulate_preempt and (i + 1) >= args.simulate_preempt \
                    and i + 1 < args.steps:
                mgr.wait()
                print(f"simulated preemption at step {i+1}", flush=True)
                pipe.close()
                return 17
    stats = mgr.wait()
    if stats:
        print(f"final ckpt: {stats['branches']} branches "
              f"ratio={stats['raw']/max(stats['comp'],1):.2f}x")
    pipe.close()
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
