"""repro.launch — mesh construction, per-cell step builders, the multi-pod
dry-run, and the train/serve drivers."""
