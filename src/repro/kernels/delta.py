"""Pallas TPU kernel: Delta preconditioner for offset-array-like streams.

The paper's Fig. 6 mechanism: offset arrays are near-arithmetic sequences;
delta turns them into near-constant streams any LZ77 codec collapses.

Kernel semantics are *block-local* (each grid step deltas within its block;
``out[0] = x[0]`` per block); the jit'd wrapper in ``ops.py`` applies the
O(grid)-sized cross-block boundary fix-up so the composed op equals the
global ``ref.delta_ref``.  This keeps the kernel embarrassingly parallel —
no cross-block carry chain — which is the right TPU shape for what is
logically a scan.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["delta_block", "undelta_block"]

_DEF_BLOCK = 4096


def _delta_kernel(x_ref, o_ref):
    x = x_ref[...]                          # (bn,) unsigned int
    shifted = jnp.concatenate([x[:1] * 0, x[:-1]])
    o_ref[...] = x - shifted                # out[0] = x[0] (block-local)


def _undelta_kernel(d_ref, o_ref):
    o_ref[...] = jnp.cumsum(d_ref[...], dtype=d_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def delta_block(x: jnp.ndarray, *, block_n: int = _DEF_BLOCK,
                interpret: bool = True) -> jnp.ndarray:
    """Block-local delta of a 1-D unsigned-int array; N % block_n == 0."""
    (n,) = x.shape
    block_n = min(block_n, n)
    assert n % block_n == 0
    return pl.pallas_call(
        _delta_kernel,
        grid=(n // block_n,),
        in_specs=[pl.BlockSpec((block_n,), lambda i: (i,))],
        out_specs=pl.BlockSpec((block_n,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), x.dtype),
        interpret=interpret,
    )(x)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def undelta_block(d: jnp.ndarray, *, block_n: int = _DEF_BLOCK,
                  interpret: bool = True) -> jnp.ndarray:
    """Block-local inclusive cumsum (inverse of delta_block)."""
    (n,) = d.shape
    block_n = min(block_n, n)
    assert n % block_n == 0
    return pl.pallas_call(
        _undelta_kernel,
        grid=(n // block_n,),
        in_specs=[pl.BlockSpec((block_n,), lambda i: (i,))],
        out_specs=pl.BlockSpec((block_n,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), d.dtype),
        interpret=interpret,
    )(d)
