"""Public jit'd entry points for the Pallas kernels.

These present *global* semantics (exactly ``ref.py``) on top of the
block-parallel kernels, handle padding/viewing arbitrary tensors as byte
streams, and pick interpret-vs-compiled automatically (interpret on CPU —
this container — compiled on real TPU).

The composition the compressed-collective path uses::

    grads (R, C) bf16
      --qpack-->          int8 (R, C) + f32 scales (R, 1)       [4x fewer bits]
      --bitshuffle-->     bit-planes of the int8 stream          [entropy grouping]
      (wire / psum)
      --bitunshuffle/qunpack-->  grads' (lossy, error fed back)

bitshuffle-after-quantize is the paper's preconditioner insight applied on
device: int8 gradient mantissas share high bits, so bit-plane grouping makes
the stream compressible/reducible; for the collective path we use the
quantize stage only (psum needs arithmetic), but checkpoint staging uses
both (see repro.checkpoint).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from . import bitshuffle as _bs
from . import byteshuffle as _bys
from . import delta as _delta
from . import qpack as _qp
from . import ref

__all__ = [
    "default_interpret",
    "bitshuffle_bytes", "bitunshuffle_bytes",
    "byteshuffle_bytes", "byteunshuffle_bytes",
    "delta_u32", "undelta_u32",
    "quantize_int8", "dequantize_int8",
]


def default_interpret() -> bool:
    """interpret=True unless we are actually on TPU."""
    return jax.default_backend() != "tpu"


def _pick_block(n: int, pref: int, mult: int) -> int:
    """Largest divisor of n that is <= pref and a multiple of ``mult``."""
    b = min(pref, n)
    b -= b % mult
    while b > mult and n % b:
        b -= mult
    return max(b, mult)


# ---------------------------------------------------------------------------
# byte-stream views
# ---------------------------------------------------------------------------

def _as_byte_matrix(x: jnp.ndarray, itemsize: int) -> jnp.ndarray:
    """View a tensor as an (N, itemsize) uint8 matrix (bitcast, no copy)."""
    flat = x.reshape(-1)
    u8 = jax.lax.bitcast_convert_type(flat, jnp.uint8)  # (N, itemsize) for multi-byte
    if u8.ndim == 1:
        u8 = u8.reshape(-1, 1)
    if itemsize != u8.shape[-1]:
        u8 = u8.reshape(-1, itemsize)
    return u8


def bitshuffle_bytes(x: jnp.ndarray, interpret: bool | None = None) -> jnp.ndarray:
    """Bit-plane transpose of any tensor whose element count is a multiple
    of 8; returns (8*itemsize, N//8) uint8."""
    interpret = default_interpret() if interpret is None else interpret
    itemsize = x.dtype.itemsize
    mat = _as_byte_matrix(x, itemsize)
    n = mat.shape[0]
    block = _pick_block(n, _bs._DEF_BLOCK, 8)
    return _bs.bitshuffle(mat, block_n=block, interpret=interpret)


def bitunshuffle_bytes(y: jnp.ndarray, dtype, n_elems: int,
                       interpret: bool | None = None) -> jnp.ndarray:
    interpret = default_interpret() if interpret is None else interpret
    itemsize = jnp.dtype(dtype).itemsize
    block = _pick_block(n_elems, _bs._DEF_BLOCK, 8)
    mat = _bs.bitunshuffle(y, itemsize, block_n=block, interpret=interpret)
    flat = jax.lax.bitcast_convert_type(mat.reshape(-1, itemsize), dtype)
    return flat.reshape(n_elems)


def byteshuffle_bytes(x: jnp.ndarray, interpret: bool | None = None) -> jnp.ndarray:
    interpret = default_interpret() if interpret is None else interpret
    itemsize = x.dtype.itemsize
    mat = _as_byte_matrix(x, itemsize)
    block = _pick_block(mat.shape[0], _bys._DEF_BLOCK, 1)
    return _bys.byteshuffle(mat, block_n=block, interpret=interpret)


def byteunshuffle_bytes(y: jnp.ndarray, dtype, n_elems: int,
                        interpret: bool | None = None) -> jnp.ndarray:
    interpret = default_interpret() if interpret is None else interpret
    itemsize = jnp.dtype(dtype).itemsize
    block = _pick_block(n_elems, _bys._DEF_BLOCK, 1)
    mat = _bys.byteunshuffle(y, block_n=block, interpret=interpret)
    return jax.lax.bitcast_convert_type(mat.reshape(-1, itemsize), dtype).reshape(n_elems)


# ---------------------------------------------------------------------------
# delta with cross-block fix-up (global semantics == ref.delta_ref)
# ---------------------------------------------------------------------------

def delta_u32(x: jnp.ndarray, interpret: bool | None = None) -> jnp.ndarray:
    """Global delta of a 1-D uint32/uint64 array via block-local kernel +
    O(n/block) boundary correction."""
    interpret = default_interpret() if interpret is None else interpret
    (n,) = x.shape
    block = _pick_block(n, _delta._DEF_BLOCK, 1)
    d = _delta.delta_block(x, block_n=block, interpret=interpret)
    if block == n:
        return d
    # fix block heads: d[k*block] should be x[k*block] - x[k*block-1]
    heads = jnp.arange(block, n, block)
    return d.at[heads].subtract(x[heads - 1])


def undelta_u32(d: jnp.ndarray, interpret: bool | None = None) -> jnp.ndarray:
    """Global cumsum via block-local cumsum + carry propagation."""
    interpret = default_interpret() if interpret is None else interpret
    (n,) = d.shape
    block = _pick_block(n, _delta._DEF_BLOCK, 1)
    partial = _delta.undelta_block(d, block_n=block, interpret=interpret)
    if block == n:
        return partial
    tails = partial[block - 1::block]                      # (n/block,)
    carry = jnp.cumsum(tails, dtype=d.dtype) - tails       # exclusive
    return partial + jnp.repeat(carry, block)


# ---------------------------------------------------------------------------
# int8 block quantization (the compressed-collective payload)
# ---------------------------------------------------------------------------

def quantize_int8(x: jnp.ndarray, block_rows: int = 256,
                  interpret: bool | None = None):
    """Any-shape float tensor -> (int8 same-shape, f32 scales, orig shape).

    Rows of the internal (R, C) view are quantization groups; C is the
    trailing dim (or the whole tensor for 1-D).
    """
    interpret = default_interpret() if interpret is None else interpret
    shape = x.shape
    mat = x.reshape(-1, shape[-1]) if x.ndim > 1 else x.reshape(1, -1)
    r = mat.shape[0]
    block = _pick_block(r, block_rows, 1)
    q, s = _qp.qpack(mat, block_rows=block, interpret=interpret)
    return q, s, shape


def dequantize_int8(q: jnp.ndarray, s: jnp.ndarray, shape, dtype=jnp.float32,
                    interpret: bool | None = None) -> jnp.ndarray:
    interpret = default_interpret() if interpret is None else interpret
    block = _pick_block(q.shape[0], 256, 1)
    out = _qp.qunpack(q, s, dtype, block_rows=block, interpret=interpret)
    return out.reshape(shape)
