"""Pure-jnp oracles for every Pallas kernel in this package.

These are the *semantic definition* of each kernel; the Pallas versions are
tested against them over shape/dtype sweeps (tests/test_kernels.py) and the
host-side numpy preconditioners in ``repro.core.precond`` agree with them
byte-for-byte (tests assert that too, closing the loop host <-> device).
"""

from __future__ import annotations

import jax.numpy as jnp

__all__ = [
    "byteshuffle_ref", "byteunshuffle_ref",
    "bitshuffle_ref", "bitunshuffle_ref",
    "delta_ref", "undelta_ref",
    "qpack_ref", "qunpack_ref",
]


# ---------------------------------------------------------------------------
# Byte shuffle (Blosc "shuffle"): (N, itemsize) uint8 -> (itemsize, N)
# ---------------------------------------------------------------------------

def byteshuffle_ref(x: jnp.ndarray) -> jnp.ndarray:
    """x: (N, itemsize) uint8 -> (itemsize, N) uint8 (byte transpose)."""
    return x.T


def byteunshuffle_ref(y: jnp.ndarray) -> jnp.ndarray:
    """y: (itemsize, N) -> (N, itemsize)."""
    return y.T


# ---------------------------------------------------------------------------
# Bit shuffle (Blosc "bitshuffle"), little-endian bit order:
#   (N, itemsize) uint8 -> (8*itemsize, N//8) uint8,  N % 8 == 0
# ---------------------------------------------------------------------------

def bitshuffle_ref(x: jnp.ndarray) -> jnp.ndarray:
    n, itemsize = x.shape
    assert n % 8 == 0, "bitshuffle needs a multiple of 8 elements"
    shifts = jnp.arange(8, dtype=jnp.uint8)
    bits = (x[:, :, None] >> shifts[None, None, :]) & jnp.uint8(1)  # (N, I, 8)
    bits = bits.reshape(n, itemsize * 8).T                           # (8I, N)
    grp = bits.reshape(itemsize * 8, n // 8, 8)
    weights = (jnp.uint8(1) << shifts)[None, None, :]
    return jnp.sum(grp.astype(jnp.uint32) * weights.astype(jnp.uint32),
                   axis=-1).astype(jnp.uint8)                        # (8I, N//8)


def bitunshuffle_ref(y: jnp.ndarray, itemsize: int) -> jnp.ndarray:
    nbits, nover8 = y.shape
    assert nbits == 8 * itemsize
    n = nover8 * 8
    shifts = jnp.arange(8, dtype=jnp.uint8)
    bits = (y[:, :, None] >> shifts[None, None, :]) & jnp.uint8(1)   # (8I, N/8, 8)
    bits = bits.reshape(nbits, n).T                                  # (N, 8I)
    grp = bits.reshape(n, itemsize, 8)
    weights = (jnp.uint8(1) << shifts)[None, None, :]
    return jnp.sum(grp.astype(jnp.uint32) * weights.astype(jnp.uint32),
                   axis=-1).astype(jnp.uint8)                        # (N, I)


# ---------------------------------------------------------------------------
# Delta (wraparound) over unsigned integer streams
# ---------------------------------------------------------------------------

def delta_ref(x: jnp.ndarray) -> jnp.ndarray:
    """Global delta: out[0] = x[0]; out[i] = x[i] - x[i-1] (mod 2^k)."""
    prev = jnp.concatenate([x[:1] * 0, x[:-1]])
    return x - prev


def undelta_ref(d: jnp.ndarray) -> jnp.ndarray:
    return jnp.cumsum(d, dtype=d.dtype)


# ---------------------------------------------------------------------------
# Block int8 quantization (per-row scale) — the compressed-collective payload
# ---------------------------------------------------------------------------

def qpack_ref(x: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """x: (R, C) float -> (q int8 (R, C), scale f32 (R, 1)); scale = amax/127."""
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf), axis=1, keepdims=True)
    scale = amax / 127.0
    safe = jnp.where(scale == 0, 1.0, scale)
    q = jnp.clip(jnp.round(xf / safe), -127, 127).astype(jnp.int8)
    return q, scale


def qunpack_ref(q: jnp.ndarray, scale: jnp.ndarray,
                dtype=jnp.float32) -> jnp.ndarray:
    return (q.astype(jnp.float32) * scale).astype(dtype)
