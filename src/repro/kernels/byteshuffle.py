"""Pallas TPU kernel: byte Shuffle preconditioner (paper §2.2, Blosc-style).

A strided byte transpose: (N, itemsize) -> (itemsize, N).  This is the
paper's worked example (big-endian ints 1,2: ``00 00 00 01 00 00 00 02`` ->
``00 00 00 00 00 00 01 02``) as device-resident VPU work.

TPU mapping: a pure relayout.  Each grid step moves a (block_n x itemsize)
byte tile through VMEM and writes its transpose; XLA's own transpose would
do the same data movement, but routing it through Pallas keeps the
preconditioner fused with the quantize/pack stage of the compressed
collective (see kernels/ops.py: ``shuffle_qpack``).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["byteshuffle", "byteunshuffle"]

_DEF_BLOCK = 16384


def _t_kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...].T


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def byteshuffle(x: jnp.ndarray, *, block_n: int = _DEF_BLOCK,
                interpret: bool = True) -> jnp.ndarray:
    """(N, itemsize) uint8 -> (itemsize, N) uint8."""
    n, itemsize = x.shape
    block_n = min(block_n, n)
    assert n % block_n == 0
    return pl.pallas_call(
        _t_kernel,
        grid=(n // block_n,),
        in_specs=[pl.BlockSpec((block_n, itemsize), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((itemsize, block_n), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((itemsize, n), jnp.uint8),
        interpret=interpret,
    )(x)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def byteunshuffle(y: jnp.ndarray, *, block_n: int = _DEF_BLOCK,
                  interpret: bool = True) -> jnp.ndarray:
    """(itemsize, N) uint8 -> (N, itemsize) uint8."""
    itemsize, n = y.shape
    block_n = min(block_n, n)
    assert n % block_n == 0
    return pl.pallas_call(
        _t_kernel,
        grid=(n // block_n,),
        in_specs=[pl.BlockSpec((itemsize, block_n), lambda i: (0, i))],
        out_specs=pl.BlockSpec((block_n, itemsize), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, itemsize), jnp.uint8),
        interpret=interpret,
    )(y)
