"""Pallas TPU kernel: block int8 quantize/pack — the compressed-collective
payload stage.

The paper's core observation is that *structured numeric data is cheap to
move once preconditioned*.  Applied to the collective roofline term: before
a data-parallel gradient reduction, each (row) block of the gradient is
quantized to int8 with a per-row f32 scale (4x fewer bytes on the wire than
bf16->f32 reductions, 2x fewer than bf16).  ``repro.parallel.compressed``
wires this into a shard_map all-reduce with error feedback.

TPU mapping: per-row amax is a lane reduction (VPU); the divide+round is
elementwise.  Block rows are tiled through VMEM; the (rows, 1) scale output
rides in SMEM-sized blocks.  MXU is untouched — this kernel lives in the
bandwidth domain, which is exactly where the paper's technique applies.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["qpack", "qunpack"]

_DEF_ROWS = 256


def _qpack_kernel(x_ref, q_ref, s_ref):
    x = x_ref[...].astype(jnp.float32)               # (br, C)
    amax = jnp.max(jnp.abs(x), axis=1, keepdims=True)
    scale = amax / 127.0
    safe = jnp.where(scale == 0, 1.0, scale)
    q_ref[...] = jnp.clip(jnp.round(x / safe), -127, 127).astype(jnp.int8)
    s_ref[...] = scale


def _qunpack_kernel(q_ref, s_ref, o_ref, *, dtype):
    o_ref[...] = (q_ref[...].astype(jnp.float32) * s_ref[...]).astype(dtype)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def qpack(x: jnp.ndarray, *, block_rows: int = _DEF_ROWS,
          interpret: bool = True) -> tuple[jnp.ndarray, jnp.ndarray]:
    """x: (R, C) float -> (int8 (R, C), f32 scale (R, 1)). R % block_rows == 0."""
    r, c = x.shape
    block_rows = min(block_rows, r)
    assert r % block_rows == 0
    grid = (r // block_rows,)
    return pl.pallas_call(
        _qpack_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block_rows, c), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((block_rows, c), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((r, c), jnp.int8),
            jax.ShapeDtypeStruct((r, 1), jnp.float32),
        ],
        interpret=interpret,
    )(x)


@functools.partial(jax.jit, static_argnames=("dtype", "block_rows", "interpret"))
def qunpack(q: jnp.ndarray, scale: jnp.ndarray, dtype=jnp.float32, *,
            block_rows: int = _DEF_ROWS, interpret: bool = True) -> jnp.ndarray:
    """Inverse of :func:`qpack` (lossy): q * scale, cast to ``dtype``."""
    r, c = q.shape
    block_rows = min(block_rows, r)
    assert r % block_rows == 0
    grid = (r // block_rows,)
    return pl.pallas_call(
        functools.partial(_qunpack_kernel, dtype=dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, c), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, c), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r, c), dtype),
        interpret=interpret,
    )(q, scale)
