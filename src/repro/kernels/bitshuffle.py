"""Pallas TPU kernel: BitShuffle preconditioner (paper §2.2, Blosc-style).

Device-side bit transpose so tensors can be preconditioned *in HBM, before
they leave the chip* — used by the compressed-collective path and by
zero-copy checkpoint staging.  The host-side numpy twin lives in
``repro.core.precond``; semantics are defined by ``ref.bitshuffle_ref``.

TPU mapping notes (DESIGN.md §3): bitshuffle is pure VPU work — shifts,
masks and an 8-lane weighted reduction; no MXU involvement.  Tiles are
chosen so a block of (block_n x itemsize) bytes plus its (8*itemsize x
block_n/8) output fit comfortably in VMEM (default 64 KiB in + 64 KiB out
per grid step), and the lane dimension (block_n) is a multiple of 1024 so
both views keep 128-lane alignment after the internal reshapes.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["bitshuffle", "bitunshuffle"]

_DEF_BLOCK = 8192  # elements per grid step


def _bitshuffle_kernel(x_ref, o_ref):
    x = x_ref[...]                                   # (bn, I) uint8
    bn, itemsize = x.shape
    shifts = jnp.arange(8, dtype=jnp.uint8)
    bits = (x[:, :, None] >> shifts[None, None, :]) & jnp.uint8(1)
    bits = bits.reshape(bn, itemsize * 8).T          # (8I, bn)
    grp = bits.reshape(itemsize * 8, bn // 8, 8).astype(jnp.uint32)
    weights = (jnp.uint32(1) << shifts.astype(jnp.uint32))[None, None, :]
    o_ref[...] = jnp.sum(grp * weights, axis=-1).astype(jnp.uint8)


def _bitunshuffle_kernel(y_ref, o_ref):
    y = y_ref[...]                                   # (8I, bn//8) uint8
    nbits, bn8 = y.shape
    itemsize = nbits // 8
    bn = bn8 * 8
    shifts = jnp.arange(8, dtype=jnp.uint8)
    bits = (y[:, :, None] >> shifts[None, None, :]) & jnp.uint8(1)
    bits = bits.reshape(nbits, bn).T                 # (bn, 8I)
    grp = bits.reshape(bn, itemsize, 8).astype(jnp.uint32)
    weights = (jnp.uint32(1) << shifts.astype(jnp.uint32))[None, None, :]
    o_ref[...] = jnp.sum(grp * weights, axis=-1).astype(jnp.uint8)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def bitshuffle(x: jnp.ndarray, *, block_n: int = _DEF_BLOCK,
               interpret: bool = True) -> jnp.ndarray:
    """(N, itemsize) uint8 -> (8*itemsize, N//8) uint8.  N % block_n == 0."""
    n, itemsize = x.shape
    block_n = min(block_n, n)
    assert n % block_n == 0 and block_n % 8 == 0, (n, block_n)
    grid = (n // block_n,)
    return pl.pallas_call(
        _bitshuffle_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block_n, itemsize), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((8 * itemsize, block_n // 8), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((8 * itemsize, n // 8), jnp.uint8),
        interpret=interpret,
    )(x)


@functools.partial(jax.jit, static_argnames=("itemsize", "block_n", "interpret"))
def bitunshuffle(y: jnp.ndarray, itemsize: int, *, block_n: int = _DEF_BLOCK,
                 interpret: bool = True) -> jnp.ndarray:
    """(8*itemsize, N//8) uint8 -> (N, itemsize) uint8."""
    nbits, nover8 = y.shape
    assert nbits == 8 * itemsize
    n = nover8 * 8
    block_n = min(block_n, n)
    assert n % block_n == 0 and block_n % 8 == 0
    grid = (n // block_n,)
    return pl.pallas_call(
        _bitunshuffle_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((nbits, block_n // 8), lambda i: (0, i))],
        out_specs=pl.BlockSpec((block_n, itemsize), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, itemsize), jnp.uint8),
        interpret=interpret,
    )(y)
