"""Synthetic NanoAOD-like event generator — the paper's test tree.

The paper benchmarks on (a) an artificially-generated ROOT tree with 2,000
events and (b) a CMS NanoAOD file (Fig. 6).  This generator reproduces the
*structure* that drives their compression results deterministically:

* float kinematics columns (pt/eta/phi/mass) — near-incompressible mantissa
  bits, compressible exponent/sign bit-planes -> BitShuffle territory;
* small-int multiplicity and id columns — byte-sparse -> Shuffle territory;
* variable-size branches (per-event jet lists) serialized exactly like ROOT:
  a flattened payload plus a strictly-increasing **offset array** — the
  paper's §2.2 LZ4-incompressible sequence, Delta+Shuffle territory;
* monotone run/lumi/event counters.

``write_event_file`` lays these out column-wise into baskets, reproducing
Figure 1 of the paper.
"""

from __future__ import annotations

import numpy as np

from repro.core import CompressionConfig, write_arrays
from repro.core.policy import choose

__all__ = ["make_events", "write_event_file", "EVENT_BRANCHES"]

EVENT_BRANCHES = [
    "run", "luminosityBlock", "event",
    "nJet", "Jet_pt", "Jet_eta", "Jet_phi", "Jet_mass", "Jet_jetId",
    "Jet_offsets",
    "nMuon", "Muon_pt", "Muon_eta", "Muon_phi", "Muon_charge",
    "Muon_offsets",
    "MET_pt", "MET_phi",
]


def make_events(n_events: int = 2000, seed: int = 0) -> dict[str, np.ndarray]:
    rng = np.random.default_rng(seed)
    run = np.full(n_events, 362_104, np.uint32)
    lumi = (np.arange(n_events, dtype=np.uint32) // 500) + 1
    event = np.arange(1, n_events + 1, dtype=np.uint64) * 7 + 13

    njet = rng.poisson(6.0, n_events).clip(0, 32).astype(np.int32)
    total_jets = int(njet.sum())
    # pt: falling spectrum; eta: central; phi: uniform — realistic value stats
    jet_pt = (20.0 + rng.exponential(35.0, total_jets)).astype(np.float32)
    jet_eta = rng.normal(0.0, 2.0, total_jets).clip(-4.7, 4.7).astype(np.float32)
    jet_phi = rng.uniform(-np.pi, np.pi, total_jets).astype(np.float32)
    jet_mass = np.abs(rng.normal(12.0, 6.0, total_jets)).astype(np.float32)
    jet_id = rng.integers(0, 7, total_jets, dtype=np.int32)
    jet_off = np.concatenate([[0], np.cumsum(njet)]).astype(np.int64)

    nmu = rng.poisson(1.2, n_events).clip(0, 8).astype(np.int32)
    total_mu = int(nmu.sum())
    mu_pt = (3.0 + rng.exponential(18.0, total_mu)).astype(np.float32)
    mu_eta = rng.normal(0.0, 1.8, total_mu).clip(-2.4, 2.4).astype(np.float32)
    mu_phi = rng.uniform(-np.pi, np.pi, total_mu).astype(np.float32)
    mu_q = rng.choice(np.array([-1, 1], np.int32), total_mu)
    mu_off = np.concatenate([[0], np.cumsum(nmu)]).astype(np.int64)

    met_pt = np.abs(rng.normal(35.0, 18.0, n_events)).astype(np.float32)
    met_phi = rng.uniform(-np.pi, np.pi, n_events).astype(np.float32)

    return {
        "run": run, "luminosityBlock": lumi, "event": event,
        "nJet": njet, "Jet_pt": jet_pt, "Jet_eta": jet_eta,
        "Jet_phi": jet_phi, "Jet_mass": jet_mass, "Jet_jetId": jet_id,
        "Jet_offsets": jet_off,
        "nMuon": nmu, "Muon_pt": mu_pt, "Muon_eta": mu_eta,
        "Muon_phi": mu_phi, "Muon_charge": mu_q, "Muon_offsets": mu_off,
        "MET_pt": met_pt, "MET_phi": met_phi,
    }


def write_event_file(path: str, n_events: int = 2000, seed: int = 0,
                     profile: str = "analysis",
                     basket_bytes: int = 32 * 1024) -> dict:
    """Generate + write an event file under a codec profile; returns events."""
    events = make_events(n_events, seed)
    write_arrays(path, events,
                 cfg_for=lambda name, arr: choose(name, arr, profile),
                 target_basket_bytes=basket_bytes)
    return events
