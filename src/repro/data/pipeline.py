"""LM token pipeline over compressed BasketFiles.

The hot read path is the paper's "simultaneous read and decompression for
multiple physics events" (Fig. 1): a background prefetch thread reads
shard files and decompresses baskets in a thread pool while the device
computes, and tokens flow out as fixed-shape (batch, seq+1) windows.

Fault-tolerance / scale properties:
  * **deterministic host sharding** — shard files are assigned
    round-robin by (host_id, n_hosts); every host sees a disjoint stream,
    and re-running with the same ids reproduces it exactly;
  * **remote shards** — a path may be a ``repro://host:port/file.bskt``
    URL served by ``repro.remote.BasketServer``; the prefetching reader
    then pulls baskets over vectored wire requests (optionally transcoded
    decode-cheap) instead of local preads, same bytes either way;
  * **exact restart cursor** — the pipeline state is (epoch, file index,
    window index); ``state_dict()``/``load_state_dict()`` round-trip it, so
    a restore resumes mid-shard with no token skew (basket index = restart
    cursor);
  * **bounded prefetch** — a depth-limited queue, so a slow (straggler)
    consumer never lets the reader run unboundedly ahead.
"""

from __future__ import annotations

import queue
import threading
from concurrent.futures import ThreadPoolExecutor
from typing import Iterator, Optional

import numpy as np

from repro import obs
from repro.core import CompressionConfig
from repro.core.bfile import BasketFile, BasketWriter
from repro.core.policy import choose
from repro.io.engine import CompressionEngine
from repro.io.prefetch import PrefetchReader

__all__ = ["write_token_shards", "TokenPipeline"]


def write_token_shards(paths: list[str], *, vocab: int, tokens_per_shard: int,
                       seed: int = 0, profile: str = "analysis",
                       tune: bool = False, objective=None,
                       tuner=None) -> None:
    """Synthetic LM corpus: Zipf-ish token stream, one branch per shard.
    Real deployments swap the generator for a tokenized corpus; the
    container/codec path is identical.

    ``tune=True`` (or an ``objective=`` / explicit ``tuner=``) replaces the
    static profile with measurement-driven selection (repro.tune): the
    first shard runs the trial matrix on its sampled tokens, and every
    later shard reuses that cached decision — the tuner is shared across
    shards, so tuning cost is paid once per corpus, and each shard's
    header carries the decision for re-opens."""
    if tuner is None and (tune or objective is not None):
        from repro.tune import Tuner
        tuner = Tuner(objective if objective is not None else "max_read_tput",
                      fallback_profile=profile)
    for i, path in enumerate(paths):
        rng = np.random.default_rng(seed + 1000 * i)
        # Zipf-distributed ids compress like natural text-token streams
        toks = rng.zipf(1.3, tokens_per_shard).astype(np.int64)
        toks = (toks % (vocab - 2)) + 2           # reserve 0=pad, 1=eos
        toks = toks.astype(np.int32)
        with BasketWriter(path, tuner=tuner) as w:
            w.write_branch("tokens", toks,
                           None if tuner else choose("tokens", toks, profile))


class TokenPipeline:
    """Iterator of {"tokens","targets"} batches with prefetch + restart."""

    def __init__(self, paths: list[str], *, batch: int, seq_len: int,
                 host_id: int = 0, n_hosts: int = 1,
                 prefetch: int = 4, decomp_workers: int = 4,
                 prefetch_baskets: int = 4, readahead_files: int = 1,
                 seed: int = 0):
        if not paths:
            raise ValueError("no shard paths")
        self.all_paths = list(paths)
        self.my_paths = [p for i, p in enumerate(paths)
                         if i % n_hosts == host_id] or [paths[host_id % len(paths)]]
        self.batch = batch
        self.seq_len = seq_len
        self.prefetch = prefetch
        self.decomp_workers = decomp_workers
        self.prefetch_baskets = prefetch_baskets
        self.readahead_files = readahead_files
        self.seed = seed
        # restart cursor
        self.epoch = 0
        self.file_idx = 0
        self.window_idx = 0
        self._q: Optional[queue.Queue] = None
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()
        # one shared engine decompresses every shard (repro.io); a 1-deep
        # file readahead slot decompresses shard i+1 while i's windows flow
        self._io_engine: Optional[CompressionEngine] = None
        self._ra_pool: Optional[ThreadPoolExecutor] = None

    # -- cursor ----------------------------------------------------------

    def state_dict(self) -> dict:
        return {"epoch": self.epoch, "file_idx": self.file_idx,
                "window_idx": self.window_idx, "seed": self.seed}

    def load_state_dict(self, st: dict) -> None:
        self._shutdown()
        self.epoch = int(st["epoch"])
        self.file_idx = int(st["file_idx"])
        self.window_idx = int(st["window_idx"])
        self.seed = int(st.get("seed", self.seed))

    # -- iteration -------------------------------------------------------

    def _windows_of_file(self, path: str) -> np.ndarray:
        """Decompress one shard through the prefetching reader: all baskets
        scheduled on the shared engine, joined in entry order (the
        simultaneous-read-and-decompress hot path).  ``repro://`` shard
        URLs open a ``RemoteBasketFile`` instead — the same reader then
        fetches baskets as vectored wire requests."""
        if self._stop.is_set():
            # a straggler producer must not recreate the engine that
            # _shutdown just closed (it would leak); die quietly instead
            raise RuntimeError("pipeline closed")
        if self._io_engine is None:
            self._io_engine = CompressionEngine(self.decomp_workers)
        remote = path.startswith("repro://")
        if remote:
            from repro.remote import RemoteBasketFile
            bfile = RemoteBasketFile(path)
        else:
            bfile = BasketFile(path)
        try:
            reader = PrefetchReader(bfile, "tokens",
                                    ahead=self.prefetch_baskets,
                                    engine=self._io_engine)
            try:
                with obs.trace.span("pipeline.shard", cat="data", path=path,
                                    remote=remote):
                    toks = reader.read_all()
            finally:
                reader.close()
        finally:
            if remote:
                bfile.close()
        obs.counter("pipeline.shards", remote=str(remote).lower()).inc()
        w = self.seq_len + 1
        n_win = toks.size // w
        return toks[: n_win * w].reshape(n_win, w)

    def _producer(self):
        # local cursor: the consumer concurrently rewrites self.epoch/
        # file_idx/window_idx to the cursor of each *consumed* batch (the
        # state to persist), so the producer must never re-read those
        # attributes mid-run — it snapshots them once at thread start
        ra: Optional[tuple] = None       # (path, Future[windows]) readahead
        epoch, file_idx, window_idx = self.epoch, self.file_idx, self.window_idx
        try:
            while not self._stop.is_set():
                path = self.my_paths[file_idx % len(self.my_paths)]
                if ra is not None and ra[0] == path:
                    wins = ra[1].result()
                else:
                    wins = self._windows_of_file(path)
                ra = None
                if self.readahead_files and len(self.my_paths) > 1:
                    nxt = self.my_paths[(file_idx + 1)
                                        % len(self.my_paths)]
                    if self._ra_pool is None:
                        self._ra_pool = ThreadPoolExecutor(
                            1, thread_name_prefix="repro-io-ra")
                    ra = (nxt, self._ra_pool.submit(
                        self._windows_of_file, nxt))
                # deterministic per-(epoch,file) shuffle of window order
                rng = np.random.default_rng(
                    (self.seed, epoch, file_idx))
                order = rng.permutation(len(wins))
                wi = window_idx
                while wi + self.batch <= len(wins):
                    if self._stop.is_set():
                        return
                    idx = order[wi: wi + self.batch]
                    chunk = wins[idx]
                    batch = {"tokens": chunk[:, :-1].astype(np.int32),
                             "targets": chunk[:, 1:].astype(np.int32)}
                    cursor = {"epoch": epoch, "file_idx": file_idx,
                              "window_idx": wi + self.batch, "seed": self.seed}
                    self._q.put((batch, cursor))
                    obs.gauge("pipeline.queue_depth").set(self._q.qsize())
                    wi += self.batch
                window_idx = 0
                file_idx += 1
                if file_idx % len(self.my_paths) == 0:
                    epoch += 1
        except Exception as e:  # surface reader errors to the consumer
            self._q.put(e)

    def _ensure_thread(self):
        if self._thread is None or not self._thread.is_alive():
            self._stop.clear()
            self._q = queue.Queue(maxsize=self.prefetch)
            self._thread = threading.Thread(target=self._producer, daemon=True)
            self._thread.start()

    def _shutdown(self):
        if self._thread is not None:
            self._stop.set()
            try:
                while True:
                    self._q.get_nowait()
            except queue.Empty:
                pass
            self._thread.join(timeout=5)
            if self._thread.is_alive():
                # straggler still decompressing: leave the pools to it
                # (it exits at the next stop check) rather than closing
                # an engine that is mid-use
                return
            self._thread = None
        if self._ra_pool is not None:
            self._ra_pool.shutdown(wait=True, cancel_futures=True)
            self._ra_pool = None
        if self._io_engine is not None:
            self._io_engine.close()
            self._io_engine = None

    def __iter__(self) -> Iterator[dict]:
        return self

    def __next__(self) -> dict:
        self._ensure_thread()
        item = self._q.get()
        if isinstance(item, Exception):
            raise item
        batch, cursor = item
        obs.counter("pipeline.batches").inc()
        # the cursor of the batch just handed out = state to persist
        self.epoch = cursor["epoch"]
        self.file_idx = cursor["file_idx"]
        self.window_idx = cursor["window_idx"]
        return batch

    def close(self):
        self._shutdown()
