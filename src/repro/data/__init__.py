"""repro.data — the event store and token pipeline over BasketFiles."""

from .events import make_events, write_event_file, EVENT_BRANCHES
from .pipeline import TokenPipeline, write_token_shards

__all__ = ["make_events", "write_event_file", "EVENT_BRANCHES",
           "TokenPipeline", "write_token_shards"]
