"""Checkpointing through the paper's compression engine.

Every tensor in the train state is a *branch* in a BasketFile; the codec
policy (repro.core.policy) picks algo/level/preconditioner per tensor —
BitShuffle+zstd for float weights/moments, Delta+Shuffle for integer
step counters and offset-like tensors.  This is the paper's per-use-case
codec choice ("checkpoint" profile) applied at production scale.

Fault-tolerance invariants:
  * **atomic**: BasketWriter writes tmp-then-rename; a crash mid-save can
    never leave a loadable-but-wrong file, and the manifest (named
    ``MANIFEST-<step>.json``) is written only after the data file commits.
  * **async**: ``save()`` snapshots to host memory synchronously (cheap)
    and compresses/writes on a background thread — training continues
    during the multi-second compress+write of big states.
  * **resumable**: ``latest_step()`` scans manifests, ignoring any step
    whose data file is missing/truncated.
  * **elastic re-shard**: tensors are saved *unsharded* (gathered to host);
    ``restore(shardings=...)`` device_puts each tensor with the target
    mesh's NamedSharding — restoring a 256-chip checkpoint onto 512 chips
    (or 8) is the same call with a different mesh.
  * **retention**: ``keep`` most recent checkpoints are kept, the rest
    garbage-collected after a successful save.
"""

from __future__ import annotations

import json
import os
import threading
import time
from typing import Any, Optional

import jax
import numpy as np

from repro.core.bfile import BasketFile, BasketWriter
from repro.core.policy import choose

__all__ = ["CheckpointManager", "save_pytree", "load_pytree"]


def _flatten_with_paths(tree) -> dict[str, Any]:
    flat = {}

    def rec(node, prefix):
        if isinstance(node, dict):
            for k in sorted(node):
                rec(node[k], f"{prefix}{k}.")
        elif node is None:
            flat[prefix.rstrip(".") + "#none"] = None
        else:
            flat[prefix.rstrip(".")] = node

    rec(tree, "")
    return flat


def _np_view(x) -> np.ndarray:
    arr = np.asarray(jax.device_get(x))
    if arr.dtype.name == "bfloat16":        # store as raw uint16 bit pattern
        arr = arr.view(np.uint16)
    return arr


def _entry_stats(stats: dict, entry: dict) -> None:
    stats["branches"] += 1
    stats["raw"] += sum(b["meta"]["orig_len"] for b in entry["baskets"])
    stats["comp"] += sum(b["meta"]["comp_len"] for b in entry["baskets"])


def save_pytree(path: str, tree, profile: str = "checkpoint",
                extra_meta: Optional[dict] = None,
                workers: int = 0, producers: int = 1) -> dict:
    """Write a pytree of (host or device) arrays as one BasketFile.

    ``workers>0`` compresses each tensor's baskets in parallel through the
    I/O engine.  ``producers>1`` additionally shards the *tensor list*
    across producer threads, each compressing its shard into an in-memory
    BasketBuffer drained by a BufferMerger (ROOT's TBufferMerger pattern) —
    one output file, no recompression, no serialized compression.  Note:
    with ``producers>1`` branch order (hence container bytes) depends on
    thread timing; contents still round-trip identically (restore is
    name-keyed).  Byte-determinism holds for ``producers<=1`` at any
    ``workers``."""
    flat = {n: v for n, v in _flatten_with_paths(tree).items() if v is not None}
    stats = {"branches": 0, "raw": 0, "comp": 0}
    bf16_paths = [n for n, v in flat.items()
                  if hasattr(v, "dtype") and str(v.dtype) == "bfloat16"]
    meta = {"bf16": bf16_paths}
    if extra_meta:
        meta.update(extra_meta)
    meta_blob = json.dumps(meta).encode()

    if producers <= 1:
        with BasketWriter(path, workers=workers) as w:
            for name, val in flat.items():
                arr = _np_view(val)
                _entry_stats(stats, w.write_branch(
                    name, arr, choose(name, arr, profile)))
            w.write_blob("__meta__", meta_blob)
        return stats

    from repro.io.merger import BufferMerger
    names = list(flat)
    shards = [names[i::producers] for i in range(producers)]
    errors: list = []
    lock = threading.Lock()
    with BufferMerger(path, workers=workers) as m:
        def produce(shard):
            try:
                for name in shard:
                    buf = m.buffer()
                    arr = _np_view(flat[name])
                    entry = buf.write_branch(name, arr,
                                             choose(name, arr, profile))
                    m.merge(buf)
                    with lock:
                        _entry_stats(stats, entry)
            except Exception as e:  # pragma: no cover - surfaced below
                errors.append(e)

        threads = [threading.Thread(target=produce, args=(s,), daemon=True)
                   for s in shards if s]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        if errors:
            raise errors[0]
        buf = m.buffer()
        buf.write_blob("__meta__", meta_blob)
        m.merge(buf)
    return stats


def load_pytree(path: str, template=None, shardings=None, workers: int = 4,
                prefetch: int = 0):
    """Read a BasketFile back into a pytree.

    ``template``: pytree whose structure/leaf-Nones define the output (leaf
    values unused).  Without it, a flat {dotted-path: array} dict returns.
    ``shardings``: matching pytree of NamedShardings -> device_put per leaf
    (elastic re-shard).  ``prefetch>0`` = decompress-ahead reads."""
    with BasketFile(path, workers=workers, prefetch=prefetch) as f:
        meta = json.loads(bytes(f.read_branch("__meta__")).decode())
        bf16 = set(meta.get("bf16", []))

        def read(name):
            arr = f.read_branch(name, workers=workers)
            if name in bf16:
                arr = arr.view(jax.numpy.bfloat16.dtype)
            return arr

        flat = {n: read(n) for n in f.branch_names() if n != "__meta__"}
    if template is None:
        return flat, meta

    flat_t = _flatten_with_paths(template)
    flat_s = _flatten_with_paths(shardings) if shardings is not None else {}

    def rebuild(node, prefix):
        if isinstance(node, dict):
            return {k: rebuild(node[k], f"{prefix}{k}.") for k in sorted(node)}
        key = prefix.rstrip(".")
        if node is None or key + "#none" in flat_t:
            return None
        arr = flat[key]
        sh = flat_s.get(key)
        return jax.device_put(arr, sh) if sh is not None else arr

    return rebuild(template, ""), meta


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, profile: str = "checkpoint",
                 workers: int = 0, producers: int = 1):
        self.dir = str(directory)
        os.makedirs(self.dir, exist_ok=True)
        self.keep = keep
        self.profile = profile
        self.workers = workers        # basket-parallel compression width
        self.producers = producers    # tensor-parallel producer threads (merger)
        self._worker: Optional[threading.Thread] = None
        self._last_stats: Optional[dict] = None

    # -- paths -----------------------------------------------------------

    def _data_path(self, step: int) -> str:
        return os.path.join(self.dir, f"ckpt-{step:08d}.bskt")

    def _manifest_path(self, step: int) -> str:
        return os.path.join(self.dir, f"MANIFEST-{step:08d}.json")

    # -- save ------------------------------------------------------------

    def save(self, step: int, tree, extra_meta: Optional[dict] = None,
             wait: bool = False) -> None:
        """Snapshot now; compress+write in the background."""
        self.wait()                                   # one in flight at a time
        host_tree = jax.tree.map(
            lambda x: None if x is None else np.asarray(jax.device_get(x)),
            tree, is_leaf=lambda x: x is None)

        def work():
            t0 = time.monotonic()
            stats = save_pytree(self._data_path(step), host_tree,
                                self.profile, extra_meta,
                                workers=self.workers,
                                producers=self.producers)
            manifest = {"step": step, "time": time.time(),
                        "wall_s": time.monotonic() - t0, **stats}
            tmp = self._manifest_path(step) + ".tmp"
            with open(tmp, "w") as fh:
                json.dump(manifest, fh)
            os.replace(tmp, self._manifest_path(step))
            self._last_stats = manifest
            self._gc()

        self._worker = threading.Thread(target=work, daemon=True)
        self._worker.start()
        if wait:
            self.wait()

    def wait(self) -> Optional[dict]:
        if self._worker is not None:
            self._worker.join()
            self._worker = None
        return self._last_stats

    # -- restore ---------------------------------------------------------

    def steps(self) -> list[int]:
        out = []
        for fn in os.listdir(self.dir):
            if fn.startswith("MANIFEST-") and fn.endswith(".json"):
                step = int(fn[len("MANIFEST-"):-len(".json")])
                if os.path.exists(self._data_path(step)):
                    out.append(step)
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        st = self.steps()
        return st[-1] if st else None

    def restore(self, step: Optional[int] = None, template=None,
                shardings=None):
        """Load a step (default latest).  Returns (tree, meta)."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        return load_pytree(self._data_path(step), template, shardings)

    # -- retention -------------------------------------------------------

    def _gc(self):
        steps = self.steps()
        for s in steps[: max(len(steps) - self.keep, 0)]:
            for p in (self._data_path(s), self._manifest_path(s)):
                try:
                    os.remove(p)
                except FileNotFoundError:
                    pass
