"""Checkpointing through the paper's compression engine.

Every tensor in the train state is a *branch* in a BasketFile; the codec
policy (repro.core.policy) picks algo/level/preconditioner per tensor —
BitShuffle+zstd for float weights/moments, Delta+Shuffle for integer
step counters and offset-like tensors.  This is the paper's per-use-case
codec choice ("checkpoint" profile) applied at production scale.

Fault-tolerance invariants:
  * **atomic**: BasketWriter writes tmp-then-rename; a crash mid-save can
    never leave a loadable-but-wrong file, and the manifest (named
    ``MANIFEST-<step>.json``) is written only after the data file commits.
  * **async + streamed**: ``save()`` compresses/writes on a background
    thread while training continues.  Tensors are staged device→host in
    chunked, double-buffered ``copy_to_host_async`` slices that feed the
    basket compressor as they land (``staging="stream"``) — D2H transfer
    overlaps compression and peak host memory drops from ~2× state size
    (the old whole-tree snapshot) to ~``stage_depth`` baskets per
    producer.  jax arrays are immutable, so the background stream reads
    the live state safely; a training step that *donates* its state
    buffers must pass ``snapshot=True`` (or use ``staging="gather"``),
    which restores the old copy-then-write behavior.
  * **resumable**: ``latest_step()`` scans manifests, ignoring any step
    whose data file is missing/truncated.
  * **elastic re-shard**: tensors are saved *unsharded* (gathered to host);
    ``restore(shardings=...)`` device_puts each tensor with the target
    mesh's NamedSharding — restoring a 256-chip checkpoint onto 512 chips
    (or 8) is the same call with a different mesh.  ``load_pytree``
    device_puts each branch as it decodes, so the full host dict never
    materializes alongside the device copy.
  * **retention**: ``keep`` most recent checkpoints are kept, the rest
    garbage-collected after a successful save.
"""

from __future__ import annotations

import itertools
import json
import logging
import os
import threading
import time
from collections import deque
from typing import Any, Optional

import jax
import numpy as np

from repro import obs
from repro.core.basket import basket_rows, split_array
from repro.core.bfile import (BasketFile, BasketWriter, CorruptBasketError,
                              TruncatedContainerError, _fsync_dir)
from repro.core.policy import choose

_LOG = logging.getLogger("repro.checkpoint")

__all__ = ["CheckpointManager", "save_pytree", "load_pytree"]

_TARGET_BASKET_BYTES = 1 << 20


def _flatten_with_paths(tree) -> dict[str, Any]:
    flat = {}

    def rec(node, prefix):
        if isinstance(node, dict):
            for k in sorted(node):
                rec(node[k], f"{prefix}{k}.")
        elif node is None:
            flat[prefix.rstrip(".") + "#none"] = None
        else:
            flat[prefix.rstrip(".")] = node

    rec(tree, "")
    return flat


def _np_view(x) -> np.ndarray:
    arr = np.asarray(jax.device_get(x))
    if arr.dtype.name == "bfloat16":        # store as raw uint16 bit pattern
        arr = arr.view(np.uint16)
    return arr


def _entry_stats(stats: dict, entry: dict) -> None:
    stats["branches"] += 1
    stats["raw"] += sum(b["meta"]["orig_len"] for b in entry["baskets"])
    stats["comp"] += sum(b["meta"]["comp_len"] for b in entry["baskets"])


# ---------------------------------------------------------------------------
# device→host staging
# ---------------------------------------------------------------------------

def _device_chunk_stream(x, rows_per: int, bf16: bool, stage_depth: int = 2):
    """Yield (start, count, host buffer) row-slices of a device array.

    Up to ``stage_depth`` slices are in flight: each is sliced on device
    and started toward the host with ``copy_to_host_async`` before the
    previous one is consumed, so D2H transfer overlaps the caller's
    compression.  Chunk boundaries equal :func:`split_array`'s
    (``basket_rows``), keeping the container byte-identical to the
    gather-then-split path."""
    n = x.shape[0]
    pending: deque = deque()
    starts = range(0, n, rows_per)
    it = iter(starts)
    exhausted = False
    while pending or not exhausted:
        while not exhausted and len(pending) < max(stage_depth, 1):
            try:
                s = next(it)
            except StopIteration:
                exhausted = True
                break
            sl = x[s:min(s + rows_per, n)]
            try:
                sl.copy_to_host_async()
            except Exception:       # pragma: no cover - backend-dependent
                pass
            pending.append((s, sl))
        if pending:
            s, sl = pending.popleft()
            arr = np.asarray(sl)
            if bf16:
                arr = arr.view(np.uint16)
            arr = np.ascontiguousarray(arr)
            yield s, arr.shape[0], memoryview(arr).cast("B")


def _branch_cfg(name: str, probe: np.ndarray, profile: str, tuner):
    """Static policy or measured tuner decision for one branch probe."""
    if tuner is not None:
        return tuner.config_for(name, probe)
    return choose(name, probe, profile)


def _branch_stream(name: str, val, profile: str,
                   target_basket_bytes: int = _TARGET_BASKET_BYTES,
                   stage_depth: int = 2, tuner=None):
    """(dtype_str, shape, chunk_iter, cfg) for one tensor.

    Device arrays stream through :func:`_device_chunk_stream`; host arrays
    split into zero-copy views.  The codec policy (or tuner) probes only
    the first staged chunk — stratified windows of that chunk — so no
    full-tensor host copy is ever made.  The gather path probes the whole
    array, so a device tensor whose statistics differ between its first
    basket and the rest may pick a different (still correct) config than
    the gather path; contents always round-trip."""
    if not isinstance(val, jax.Array) or val.ndim == 0 or val.shape[0] == 0:
        arr = _np_view(val)
        return (arr.dtype.str, arr.shape,
                split_array(arr, target_basket_bytes),
                _branch_cfg(name, arr, profile, tuner))
    bf16 = str(val.dtype) == "bfloat16"
    np_dtype = np.dtype(np.uint16) if bf16 else np.dtype(val.dtype)
    shape = tuple(val.shape)
    rows_per = basket_rows(shape, np_dtype.itemsize, target_basket_bytes)
    chunks = _device_chunk_stream(val, rows_per, bf16, stage_depth)
    first = next(chunks)
    probe = np.frombuffer(first[2], dtype=np_dtype)
    cfg = _branch_cfg(name, probe, profile, tuner)
    return (np_dtype.str, shape, itertools.chain([first], chunks), cfg)


def save_pytree(path: str, tree, profile: str = "checkpoint",
                extra_meta: Optional[dict] = None,
                workers: int = 0, producers: int = 1,
                staging: str = "stream", stage_depth: int = 2,
                tuner=None, objective=None, parity: int = 0) -> dict:
    """Write a pytree of (host or device) arrays as one BasketFile.

    ``workers>0`` compresses each tensor's baskets in parallel through the
    I/O engine.  ``producers>1`` additionally shards the *tensor list*
    across producer threads, each compressing its shard into an in-memory
    BasketBuffer drained by a BufferMerger (ROOT's TBufferMerger pattern) —
    one output file, no recompression, no serialized compression.  Note:
    with ``producers>1`` branch order (hence container bytes) depends on
    thread timing; contents still round-trip identically (restore is
    name-keyed).  Byte-determinism holds for ``producers<=1`` at any
    ``workers`` and either ``staging`` mode (identical basket boundaries).

    ``staging="stream"`` (default) never materializes a tensor on host:
    device arrays stage down in ≤``stage_depth`` in-flight basket-sized
    ``copy_to_host_async`` slices that feed the compressor as they land —
    peak extra host memory is ~``stage_depth`` baskets per producer
    instead of the whole tree.  ``staging="gather"`` is the old behavior
    (full ``device_get`` per tensor before compression).

    ``objective=`` (or an explicit ``tuner=``) switches per-branch codec
    selection from the static ``profile`` heuristic to measurement-driven
    tuning (repro.tune): each tensor's config is chosen from trial
    compressions on sampled payloads, decisions persist in the file
    header, and a manager-held tuner reuses them across steps.

    ``parity=k`` additionally writes a ``<path>.parity`` XOR sidecar
    (DESIGN.md §15) so a later bit-rotted basket heals in place on
    restore — the container bytes themselves are unchanged."""
    if staging not in ("stream", "gather"):
        raise ValueError(f"staging must be 'stream' or 'gather', got {staging!r}")
    if tuner is None and objective is not None:
        from repro.tune import Tuner
        tuner = Tuner(objective, fallback_profile=profile)
    flat = {n: v for n, v in _flatten_with_paths(tree).items() if v is not None}
    stats = {"branches": 0, "raw": 0, "comp": 0}
    bf16_paths = [n for n, v in flat.items()
                  if hasattr(v, "dtype") and str(v.dtype) == "bfloat16"]
    meta = {"bf16": bf16_paths}
    if extra_meta:
        meta.update(extra_meta)
    meta_blob = json.dumps(meta).encode()

    def branch_args(name):
        if staging == "stream":
            return _branch_stream(name, flat[name], profile,
                                  stage_depth=stage_depth, tuner=tuner)
        arr = _np_view(flat[name])
        return (arr.dtype.str, arr.shape,
                split_array(arr, _TARGET_BASKET_BYTES),
                _branch_cfg(name, arr, profile, tuner))

    def lend_engine(engine):
        # trial matrices fan out through the write's own engine (C-codec
        # pools); returns a restore callback — a manager-held tuner must
        # not keep a reference to an engine that closes with this save
        if tuner is not None and tuner.engine is None and engine is not None:
            tuner.engine = engine
            return lambda: setattr(tuner, "engine", None)
        return lambda: None

    t0 = time.perf_counter()
    if producers <= 1:
        with obs.trace.span("ckpt.save", cat="ckpt", path=path,
                            branches=len(flat)), \
                obs.profile.mem_phase("ckpt.save"), \
                BasketWriter(path, workers=workers, tuner=tuner,
                             parity=parity) as w:
            unlend = lend_engine(w._engine)
            try:
                for name in flat:
                    dtype, shape, chunks, cfg = branch_args(name)
                    with obs.trace.span("ckpt.write_branch", cat="ckpt",
                                        branch=name):
                        _entry_stats(stats, w.write_branch_chunks(
                            name, dtype=dtype, shape=shape, chunks=chunks,
                            cfg=cfg))
                w.write_blob("__meta__", meta_blob)
            finally:
                unlend()
        obs.histogram("ckpt.save_s").observe(time.perf_counter() - t0)
        obs.counter("ckpt.saves").inc()
        return stats

    from repro.io.merger import BufferMerger
    names = list(flat)
    shards = [names[i::producers] for i in range(producers)]
    errors: list = []
    lock = threading.Lock()
    with obs.trace.span("ckpt.save", cat="ckpt", path=path,
                        branches=len(flat)), \
            obs.profile.mem_phase("ckpt.save"), \
            BufferMerger(path, workers=workers, tuner=tuner,
                         parity=parity) as m:
        unlend = lend_engine(m._engine)

        def produce(shard):
            try:
                for name in shard:
                    buf = m.buffer()
                    dtype, shape, chunks, cfg = branch_args(name)
                    with obs.trace.span("ckpt.write_branch", cat="ckpt",
                                        branch=name):
                        entry = buf.write_branch_chunks(
                            name, dtype=dtype, shape=shape, chunks=chunks,
                            cfg=cfg)
                    m.merge(buf)
                    with lock:
                        _entry_stats(stats, entry)
            except Exception as e:  # pragma: no cover - surfaced below
                errors.append(e)

        threads = [threading.Thread(target=produce, args=(s,), daemon=True)
                   for s in shards if s]
        try:
            for t in threads:
                t.start()
            for t in threads:
                t.join()
        finally:
            unlend()
        if errors:
            raise errors[0]
        buf = m.buffer()
        buf.write_blob("__meta__", meta_blob)
        m.merge(buf)
    obs.histogram("ckpt.save_s").observe(time.perf_counter() - t0)
    obs.counter("ckpt.saves").inc()
    return stats


def load_pytree(path: str, template=None, shardings=None, workers: int = 4,
                prefetch: int = 0, heal: Optional[str] = None):
    """Read a BasketFile back into a pytree.

    ``template``: pytree whose structure/leaf-Nones define the output (leaf
    values unused).  Without it, a flat {dotted-path: array} dict returns.
    ``shardings``: matching pytree of NamedShardings -> device_put per leaf
    (elastic re-shard).  ``prefetch>0`` = decompress-ahead reads.

    Branches are ``device_put`` *as they decode* (when a sharding is
    given), so the host copy of each tensor is dropped immediately instead
    of the whole host dict coexisting with the device tree.

    ``heal="auto"``: a checksum-failing basket is reconstructed in place
    from the ``<path>.parity`` sidecar (when one exists) before the read
    fails — the restore-side half of ``save_pytree(parity=k)``."""
    flat_s = _flatten_with_paths(shardings) if shardings is not None else {}
    t0 = time.perf_counter()
    with obs.trace.span("ckpt.load", cat="ckpt", path=path), \
            obs.profile.mem_phase("ckpt.load"), \
            BasketFile(path, workers=workers, prefetch=prefetch,
                       heal=heal) as f:
        meta = json.loads(bytes(f.read_branch("__meta__")).decode())
        bf16 = set(meta.get("bf16", []))

        def read(name):
            with obs.trace.span("ckpt.read_branch", cat="ckpt", branch=name):
                arr = f.read_branch(name, workers=workers)
            if name in bf16:
                arr = arr.view(jax.numpy.bfloat16.dtype)
            sh = flat_s.get(name)
            # staging symmetry: put each branch on device now, free host
            return jax.device_put(arr, sh) if sh is not None else arr

        flat = {n: read(n) for n in f.branch_names() if n != "__meta__"}
    obs.histogram("ckpt.load_s").observe(time.perf_counter() - t0)
    obs.counter("ckpt.loads").inc()
    if template is None:
        return flat, meta

    flat_t = _flatten_with_paths(template)

    def rebuild(node, prefix):
        if isinstance(node, dict):
            return {k: rebuild(node[k], f"{prefix}{k}.") for k in sorted(node)}
        key = prefix.rstrip(".")
        if node is None or key + "#none" in flat_t:
            return None
        return flat[key]

    return rebuild(template, ""), meta


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, profile: str = "checkpoint",
                 workers: int = 0, producers: int = 1,
                 tune: bool = False, objective=None, parity: int = 0):
        self.dir = str(directory)
        os.makedirs(self.dir, exist_ok=True)
        self.keep = keep
        self.profile = profile
        self.workers = workers        # basket-parallel compression width
        self.producers = producers    # tensor-parallel producer threads (merger)
        self.parity = int(parity)     # XOR parity sidecar stripe width (0 = off)
        # measurement-driven codec selection: one tuner lives for the
        # manager's lifetime, so step N+1 reuses step N's decisions (zero
        # re-measurement) and the drift detector spans steps
        self._tuner = None
        if tune or objective is not None:
            from repro.tune import OBJECTIVES, Tuner
            obj = objective if objective is not None else (
                profile if profile in OBJECTIVES else "checkpoint")
            self._tuner = Tuner(obj, fallback_profile=profile)
        self._worker: Optional[threading.Thread] = None
        self._last_stats: Optional[dict] = None
        self._error: Optional[BaseException] = None

    # -- paths -----------------------------------------------------------

    def _data_path(self, step: int) -> str:
        return os.path.join(self.dir, f"ckpt-{step:08d}.bskt")

    def _manifest_path(self, step: int) -> str:
        return os.path.join(self.dir, f"MANIFEST-{step:08d}.json")

    # -- save ------------------------------------------------------------

    def save(self, step: int, tree, extra_meta: Optional[dict] = None,
             wait: bool = False, snapshot: bool = False) -> None:
        """Compress+write in the background; training continues.

        By default no host snapshot is taken: the background thread stages
        each (immutable) device tensor down in basket-sized double-buffered
        slices, overlapping D2H with compression and bounding peak host
        memory at a few baskets instead of a full state copy.
        ``snapshot=True`` restores the old gather-everything-first behavior
        — required when the training step *donates* the state buffers (a
        donated array must not be read after the next step dispatches; a
        donated-away array makes the background save fail, and that
        failure re-raises from the next ``save()``/``wait()``)."""
        self.wait()                                   # one in flight at a time
        if self._tuner is not None and not self._tuner.decisions:
            # re-open: seed the tuner from the latest checkpoint's header
            # so resumed runs never re-measure what a prior run decided
            last = self.latest_step()
            if last is not None:
                from repro.tune import load_decisions
                try:
                    self._tuner.load(load_decisions(self._data_path(last)))
                except Exception:
                    pass            # unreadable/malformed header: just re-tune
        if snapshot:
            src = jax.tree.map(
                lambda x: None if x is None else np.asarray(jax.device_get(x)),
                tree, is_leaf=lambda x: x is None)
        else:
            src = tree

        def work():
            try:
                t0 = time.monotonic()
                stats = save_pytree(self._data_path(step), src,
                                    self.profile, extra_meta,
                                    workers=self.workers,
                                    producers=self.producers,
                                    staging="stream",
                                    tuner=self._tuner,
                                    parity=self.parity)
                manifest = {"step": step, "time": time.time(),
                            "wall_s": time.monotonic() - t0, **stats}
                # atomic commit: tmp + fsync + rename + fsync dir — the
                # manifest is the "this step exists" marker, so it must
                # never be observable half-written (or survive a crash
                # pointing at a container the kernel never flushed)
                tmp = self._manifest_path(step) + ".tmp"
                try:
                    with open(tmp, "w") as fh:
                        json.dump(manifest, fh)
                        fh.flush()
                        os.fsync(fh.fileno())
                    os.replace(tmp, self._manifest_path(step))
                except BaseException:
                    try:
                        os.remove(tmp)
                    except OSError:
                        pass
                    raise
                _fsync_dir(self.dir)
                self._last_stats = manifest
                self._gc()
            except BaseException as e:   # surfaced by the next save()/wait()
                self._error = e

        self._worker = threading.Thread(target=work, daemon=True)
        self._worker.start()
        if wait:
            self.wait()

    def wait(self) -> Optional[dict]:
        """Join any in-flight save; re-raises a background-save failure (a
        silently lost checkpoint must not look like a successful one)."""
        if self._worker is not None:
            self._worker.join()
            self._worker = None
        if self._error is not None:
            err, self._error = self._error, None
            raise RuntimeError("background checkpoint save failed "
                               "(state donated before the save finished? "
                               "pass save(..., snapshot=True))") from err
        return self._last_stats

    # -- restore ---------------------------------------------------------

    def steps(self) -> list[int]:
        out = []
        for fn in os.listdir(self.dir):
            if fn.startswith("MANIFEST-") and fn.endswith(".json"):
                step = int(fn[len("MANIFEST-"):-len(".json")])
                if os.path.exists(self._data_path(step)):
                    out.append(step)
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        st = self.steps()
        return st[-1] if st else None

    def restore(self, step: Optional[int] = None, template=None,
                shardings=None):
        """Load a step (default latest).  Returns (tree, meta).

        Every load opens with ``heal="auto"``, so a bit-rotted basket in a
        ``parity=k``-saved checkpoint is first repaired in place.  With
        ``step=None`` the manager additionally walks known steps newest →
        oldest: a checkpoint that is torn or corrupt *beyond healing*
        is skipped (logged, ``repair.ckpt.skipped``) and the previous
        known-good step loads instead — a rotted latest checkpoint costs a
        few steps of retraining, never the run.  An explicit ``step=``
        means "this step or nothing": the heal is still attempted but the
        failure surfaces to the caller."""
        if step is not None:
            return load_pytree(self._data_path(step), template, shardings,
                               heal="auto")
        candidates = sorted(self.steps(), reverse=True)
        if not candidates:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        skipped: list[tuple[int, str]] = []
        for s in candidates:
            try:
                return load_pytree(self._data_path(s), template, shardings,
                                   heal="auto")
            except (CorruptBasketError, TruncatedContainerError) as e:
                _LOG.warning("checkpoint step %d unloadable (%s); "
                             "falling back to previous step", s, e)
                obs.counter("repair.ckpt.skipped").inc()
                skipped.append((s, str(e)))
        from repro.core.basket import ChecksumError
        raise ChecksumError(
            "every checkpoint in %s is corrupt beyond healing; skipped %s"
            % (self.dir, "; ".join(f"step {s}: {m}" for s, m in skipped)))

    # -- retention -------------------------------------------------------

    def _gc(self):
        from repro.io import fdcache
        steps = self.steps()
        for s in steps[: max(len(steps) - self.keep, 0)]:
            for p in (self._data_path(s), self._manifest_path(s),
                      self._data_path(s) + ".parity",
                      self._data_path(s) + ".scrub"):
                fdcache.invalidate(p)   # a cached fd would pin the inode
                try:
                    os.remove(p)
                except FileNotFoundError:
                    pass
