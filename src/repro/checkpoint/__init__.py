"""repro.checkpoint — basket-format checkpoints with per-tensor codec
policy, async+atomic writes, retention, and elastic re-shard on restore."""

from .manager import CheckpointManager, save_pytree, load_pytree

__all__ = ["CheckpointManager", "save_pytree", "load_pytree"]
