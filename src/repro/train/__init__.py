"""repro.train — from-scratch AdamW, mixed-precision train step with
gradient accumulation, clipping, LR schedules, and the (beyond-paper)
compressed-gradient hook."""

from .optim import adamw_init, adamw_update, clip_by_global_norm, warmup_cosine
from .step import (TrainState, make_train_step, init_train_state,
                   abstract_train_state)

__all__ = ["adamw_init", "adamw_update", "clip_by_global_norm",
           "warmup_cosine", "TrainState", "make_train_step",
           "init_train_state", "abstract_train_state"]
