"""Mixed-precision train step: fp32 master params, bf16 compute, fp32
grads, AdamW; optional microbatch gradient accumulation (lax.scan) and the
error-feedback int8 gradient-compression hook (the paper's preconditioner
insight applied to the DP collective — see DESIGN.md §2.3; the wire-level
shard_map variant lives in repro.parallel.compressed).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from .optim import adamw_init, adamw_update, clip_by_global_norm, warmup_cosine

__all__ = ["TrainState", "init_train_state", "make_train_step"]


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class TrainState:
    params: Any
    opt: Any
    step: jnp.ndarray
    err: Any = None          # error-feedback residual (grad compression)


def init_train_state(model, key, *, bf16_moments: bool = False,
                     compress_grads: bool = False) -> TrainState:
    params = model.init(key, dtype=jnp.float32)
    opt = adamw_init(params, bf16_moments=bf16_moments)
    err = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.bfloat16), params) \
        if compress_grads else None
    return TrainState(params=params, opt=opt, step=jnp.zeros((), jnp.int32),
                      err=err)


def abstract_train_state(model, *, bf16_moments: bool = False,
                         compress_grads: bool = False) -> TrainState:
    """ShapeDtypeStruct twin of init_train_state (dry-run, no allocation)."""
    params = model.abstract(dtype=jnp.float32)
    mdt = jnp.bfloat16 if bf16_moments else jnp.float32
    sds = lambda dt: (lambda p: jax.ShapeDtypeStruct(p.shape, dt))
    opt = {"m": jax.tree.map(sds(mdt), params),
           "v": jax.tree.map(sds(mdt), params),
           "count": jax.ShapeDtypeStruct((), jnp.int32)}
    err = jax.tree.map(sds(jnp.bfloat16), params) if compress_grads else None
    return TrainState(params=params, opt=opt,
                      step=jax.ShapeDtypeStruct((), jnp.int32), err=err)


def _quantize_ef(g, e):
    """int8 error-feedback quantization of one gradient tensor.

    Simulates the compressed DP reduction's numerics inside the jit'd step:
    the value the optimizer sees is dequant(quant(g + err)); the residual
    carries to the next step.  (The wire-level version quantizes before the
    all-reduce — repro.parallel.compressed — with identical numerics.)
    """
    gf = g.astype(jnp.float32) + e.astype(jnp.float32)
    flat = gf.reshape(-1)
    amax = jnp.max(jnp.abs(flat))
    scale = jnp.where(amax == 0, 1.0, amax / 127.0)
    q = jnp.clip(jnp.round(flat / scale), -127, 127)
    deq = (q * scale).reshape(g.shape)
    return deq.astype(g.dtype), (gf - deq).astype(e.dtype)


def make_train_step(model, *, peak_lr=3e-4, warmup=100, total_steps=10_000,
                    clip_norm: float = 1.0, accum: int = 1,
                    bf16_moments: bool = False,
                    compress_grads: bool = False,
                    bf16_grads: bool = False,
                    weight_decay: float = 0.1) -> Callable:
    """Returns train_step(state, batch) -> (state, metrics).

    ``accum > 1``: batch leaves must be shaped (accum, micro, ...); grads
    are averaged over microbatches via lax.scan (bounded-memory, and the
    unit of straggler-tolerant re-dispatch in the training loop).

    ``bf16_grads`` (§Perf D): differentiate with respect to the bf16 cast
    of the params, so gradient DP reductions move bf16 on the wire (half
    the collective bytes; the optimizer still updates fp32 masters).
    """
    compute_dtype = jnp.dtype(model.cfg.dtype)

    def cast(p):
        return p.astype(compute_dtype) if p.dtype == jnp.float32 else p

    def loss_fn(params, batch):
        return model.loss(jax.tree.map(cast, params), batch)

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)
    grad_fn_bf16 = jax.value_and_grad(model.loss, has_aux=True)

    def one_micro(params, mb):
        if bf16_grads:
            (loss, metrics), grads = grad_fn_bf16(jax.tree.map(cast, params), mb)
        else:
            (loss, metrics), grads = grad_fn(params, mb)
        return grads, metrics

    def train_step(state: TrainState, batch):
        params = state.params
        if accum == 1:
            grads, metrics = one_micro(params, batch)
        else:
            def body(acc, mb):
                g, m = one_micro(params, mb)
                acc = jax.tree.map(lambda a, b: a + b.astype(jnp.float32)
                                   / accum, acc, g)
                return acc, m

            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            grads, ms = jax.lax.scan(body, zeros, batch)
            metrics = jax.tree.map(lambda x: x.mean(0), ms)

        if compress_grads:
            flat_g, tdef = jax.tree.flatten(grads)
            flat_e = tdef.flatten_up_to(state.err)
            pairs = [_quantize_ef(g, e) for g, e in zip(flat_g, flat_e)]
            grads = tdef.unflatten([p[0] for p in pairs])
            new_err = tdef.unflatten([p[1] for p in pairs])
        else:
            new_err = state.err

        grads, gnorm = clip_by_global_norm(grads, clip_norm)
        lr = warmup_cosine(state.step, peak_lr=peak_lr, warmup=warmup,
                           total=total_steps)
        new_params, new_opt = adamw_update(grads, state.opt, params, lr,
                                           weight_decay=weight_decay)
        metrics = dict(metrics)
        metrics["grad_norm"] = gnorm
        metrics["lr"] = lr
        new_state = TrainState(params=new_params, opt=new_opt,
                               step=state.step + 1, err=new_err)
        return new_state, metrics

    return train_step
