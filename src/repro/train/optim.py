"""AdamW + utilities, from scratch (no optax in this environment).

Moments are fp32 trees shaped like the params; their sharding is derived
in ``repro.parallel.opt_shardings`` (ZeRO-1 over "data" where divisible).
``bf16_moments`` halves optimizer HBM for the 400B config (EXPERIMENTS.md).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["adamw_init", "adamw_update", "clip_by_global_norm", "warmup_cosine"]


def adamw_init(params, bf16_moments: bool = False):
    mdt = jnp.bfloat16 if bf16_moments else jnp.float32
    zeros = lambda p: jnp.zeros(p.shape, mdt)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "count": jnp.zeros((), jnp.int32),
    }


def adamw_update(grads, opt, params, lr, *, b1=0.9, b2=0.95, eps=1e-8,
                 weight_decay=0.1):
    count = opt["count"] + 1
    cf = count.astype(jnp.float32)
    bc1 = 1.0 - b1 ** cf
    bc2 = 1.0 - b2 ** cf

    def upd(g, m, v, p):
        gf = g.astype(jnp.float32)
        m_new = b1 * m.astype(jnp.float32) + (1 - b1) * gf
        v_new = b2 * v.astype(jnp.float32) + (1 - b2) * gf * gf
        step = lr * (m_new / bc1) / (jnp.sqrt(v_new / bc2) + eps)
        step = step + lr * weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - step).astype(p.dtype), \
               m_new.astype(m.dtype), v_new.astype(v.dtype)

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(opt["m"])
    flat_v = tdef.flatten_up_to(opt["v"])
    out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "count": count}


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree.leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-12))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype),
                        grads), gn


def warmup_cosine(step, *, peak_lr=3e-4, warmup=100, total=10_000,
                  min_ratio=0.1):
    s = step.astype(jnp.float32)
    warm = peak_lr * s / max(warmup, 1)
    prog = jnp.clip((s - warmup) / max(total - warmup, 1), 0.0, 1.0)
    cos = peak_lr * (min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
    return jnp.where(s < warmup, warm, cos)
