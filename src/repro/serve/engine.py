"""Slot-based batched serving engine.

The analysis-side operating point from the paper (§1: "little per-event
CPU available, decompression-speed-bound") is serving: the engine reads
prompt batches from compressed BasketFiles, keeps a fixed pool of B cache
slots, and runs jit'd prefill/decode steps; finished slots are refilled
from the queue (continuous batching).  Decode state is a single stacked
cache pytree so one decode_step serves all slots.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs

__all__ = ["ServeEngine", "sample_logits"]


def sample_logits(logits, key, temperature: float = 0.0):
    """Greedy (t=0) or temperature sampling.  logits: (B, V) fp32."""
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jax.random.categorical(key, logits / temperature, axis=-1).astype(jnp.int32)


@dataclasses.dataclass
class _Slot:
    req_id: int = -1
    pos: int = 0
    out: list = dataclasses.field(default_factory=list)
    max_new: int = 0
    active: bool = False


class ServeEngine:
    """Continuous-batching engine over one model's prefill/decode steps.

    All slots share one prompt length per prefill call (bucketed); decode
    is one token across every active slot per step.
    """

    def __init__(self, model, params, *, batch_slots: int, max_len: int,
                 eos_id: int = 1, temperature: float = 0.0, seed: int = 0):
        self.model = model
        self.params = params
        self.B = batch_slots
        self.max_len = max_len
        self.eos_id = eos_id
        self.temperature = temperature
        self.key = jax.random.key(seed)
        self.slots = [_Slot() for _ in range(batch_slots)]
        self.cache = model.init_cache(batch_slots, max_len)
        self._prefill = jax.jit(
            lambda p, b: model.prefill(p, b, max_len=max_len))
        self._decode = jax.jit(model.decode_step)
        self._queue: list = []
        self._done: dict = {}
        self._next_id = 0

    # -- public API ------------------------------------------------------

    def submit(self, tokens: np.ndarray, max_new: int = 32) -> int:
        rid = self._next_id
        self._next_id += 1
        self._queue.append((rid, np.asarray(tokens, np.int32), max_new))
        obs.counter("serve.requests").inc()
        return rid

    def run(self) -> dict:
        """Drain the queue; returns {req_id: np.ndarray(generated tokens)}."""
        while self._queue or any(s.active for s in self.slots):
            self._admit()
            self._decode_round()
        out, self._done = self._done, {}
        return out

    # -- internals -------------------------------------------------------

    def _free_slots(self):
        return [i for i, s in enumerate(self.slots) if not s.active]

    def _admit(self):
        free = self._free_slots()
        if not free or not self._queue:
            return
        take = self._queue[: len(free)]
        del self._queue[: len(take)]
        # bucket to one prompt length (pad left with 0s, mask via loss-free
        # prefill: we simply prefill at the bucketed length)
        plen = max(len(t) for _, t, _ in take)
        toks = np.zeros((self.B, plen), np.int32)
        for slot_i, (rid, t, max_new) in zip(free, take):
            toks[slot_i, plen - len(t):] = t
        with obs.trace.span("serve.prefill", cat="serve", slots=len(take),
                            plen=plen), \
                obs.profile.mem_phase("serve.prefill"):
            logits, cache = self._prefill(self.params,
                                          {"tokens": jnp.asarray(toks)})
        # write the prefilled rows into the engine cache
        rows = jnp.asarray(free[: len(take)], jnp.int32)
        self.cache = jax.tree.map(
            lambda full, new: full.at[:, rows].set(new[:, rows]),
            self.cache, cache)
        logits_np = np.asarray(logits, np.float32)
        for slot_i, (rid, t, max_new) in zip(free, take):
            s = self.slots[slot_i]
            s.req_id, s.pos, s.out, s.max_new, s.active = rid, plen, [], max_new, True
            first = int(np.argmax(logits_np[slot_i]))
            s.out.append(first)

    def _decode_round(self, rounds: int = 8):
        active = [i for i, s in enumerate(self.slots) if s.active]
        if not active:
            return
        for _ in range(rounds):
            active = [i for i, s in enumerate(self.slots) if s.active]
            if not active:
                return
            pos = max(self.slots[i].pos for i in active)
            if pos >= self.max_len - 1:
                for i in active:
                    self._finish(i)
                return
            last = np.zeros((self.B, 1), np.int32)
            for i in active:
                last[i, 0] = self.slots[i].out[-1]
            with obs.trace.span("serve.decode_step", cat="serve",
                                slots=len(active)), \
                    obs.profile.mem_phase("serve.decode_step"):
                logits, self.cache = self._decode(
                    self.params, self.cache, jnp.asarray(last),
                    jnp.asarray(pos, jnp.int32))
            obs.counter("serve.tokens").inc(len(active))
            self.key, sub = jax.random.split(self.key)
            nxt = np.asarray(sample_logits(logits, sub, self.temperature))
            for i in active:
                s = self.slots[i]
                tok = int(nxt[i])
                s.out.append(tok)
                s.pos = pos + 1
                if tok == self.eos_id or len(s.out) >= s.max_new:
                    self._finish(i)

    def _finish(self, slot_i: int):
        s = self.slots[slot_i]
        self._done[s.req_id] = np.asarray(s.out, np.int32)
        s.active = False
        obs.counter("serve.completed").inc()
        obs.histogram("serve.gen_tokens").observe(len(s.out))
