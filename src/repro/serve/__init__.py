"""repro.serve — batched serving: slot-based continuous batching over
jit'd prefill/decode steps."""

from .engine import ServeEngine, sample_logits

__all__ = ["ServeEngine", "sample_logits"]
